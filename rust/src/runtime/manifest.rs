//! `artifacts/manifest.json` schema — the contract between `aot.py` and
//! the Rust runtime/model layers — plus [`PlanStore`], the manifest-backed
//! persistence layer for [`SparsePlan`] coordinates (DESIGN.md §11):
//! sessions warm their plan cache from the manifest's `plan_store` key and
//! flush fresh plans back, so identification amortizes across process
//! restarts, not just within one.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{anyhow, Context, Result};

use crate::attention::exec::ExecutorKind;
use crate::attention::plan::{GroupPlan, PlanKey, SparsePlan};
use crate::attention::{CostTally, TileConfig};
use crate::coordinator::scheduler::CostConstants;
use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<Self> {
        let dtype = j.get("dtype").as_str().ok_or_else(|| anyhow!("tensor missing dtype"))?;
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("tensor missing shape"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { dtype: dtype.to_string(), shape })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub count: usize,
}

#[derive(Clone, Debug)]
pub struct WeightsSpec {
    pub file: String,
    pub total_f32: usize,
    pub params: Vec<ParamSpec>,
}

/// Mirror of `python/compile/model.py::ModelCfg`.
#[derive(Clone, Copy, Debug)]
pub struct ModelSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    pub max_seq: usize,
    pub prefill_chunk: usize,
}

/// Anchor hyperparameters baked into the `attn_anchor_*` artifacts.
#[derive(Clone, Copy, Debug)]
pub struct AnchorSpec {
    pub block: usize,
    pub theta: f64,
    pub step: usize,
    pub init_blocks: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: ModelSpec,
    pub anchor: AnchorSpec,
    pub weights: WeightsSpec,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;

        let m = j.get("model");
        let req = |node: &Json, key: &str| -> Result<usize> {
            node.get(key).as_usize().ok_or_else(|| anyhow!("model.{key} missing"))
        };
        let model = ModelSpec {
            vocab: req(m, "vocab")?,
            d_model: req(m, "d_model")?,
            n_layers: req(m, "n_layers")?,
            n_heads: req(m, "n_heads")?,
            n_kv_heads: req(m, "n_kv_heads")?,
            d_head: req(m, "d_head")?,
            d_ffn: req(m, "d_ffn")?,
            max_seq: req(m, "max_seq")?,
            prefill_chunk: req(m, "prefill_chunk")?,
        };

        let a = j.get("anchor");
        let anchor = AnchorSpec {
            block: req(a, "block")?,
            theta: a.get("theta").as_f64().ok_or_else(|| anyhow!("anchor.theta"))?,
            step: req(a, "step")?,
            init_blocks: req(a, "init_blocks")?,
        };

        let w = j.get("weights");
        let params = w
            .get("params")
            .as_arr()
            .ok_or_else(|| anyhow!("weights.params missing"))?
            .iter()
            .map(|p| -> Result<ParamSpec> {
                Ok(ParamSpec {
                    name: p.get("name").as_str().ok_or_else(|| anyhow!("param name"))?.into(),
                    shape: p
                        .get("shape")
                        .as_arr()
                        .ok_or_else(|| anyhow!("param shape"))?
                        .iter()
                        .map(|x| x.as_usize().unwrap_or(0))
                        .collect(),
                    offset: p.get("offset").as_usize().ok_or_else(|| anyhow!("param offset"))?,
                    count: p.get("count").as_usize().ok_or_else(|| anyhow!("param count"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let weights = WeightsSpec {
            file: w.get("file").as_str().unwrap_or("weights.bin").to_string(),
            total_f32: w.get("total_f32").as_usize().ok_or_else(|| anyhow!("total_f32"))?,
            params,
        };

        let artifacts = j
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow!("artifacts missing"))?
            .iter()
            .map(|a| -> Result<ArtifactSpec> {
                Ok(ArtifactSpec {
                    name: a.get("name").as_str().ok_or_else(|| anyhow!("artifact name"))?.into(),
                    file: a.get("file").as_str().ok_or_else(|| anyhow!("artifact file"))?.into(),
                    inputs: a
                        .get("inputs")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::parse)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: a
                        .get("outputs")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::parse)
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Self { model, anchor, weights, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Sanity checks used by integration tests and `selftest`.
    pub fn validate(&self) -> Result<()> {
        let mut off = 0;
        for p in &self.weights.params {
            if p.offset != off {
                return Err(anyhow!("param {} offset {} != expected {off}", p.name, p.offset));
            }
            let count: usize = p.shape.iter().product();
            if count != p.count {
                return Err(anyhow!("param {} count mismatch", p.name));
            }
            off += p.count;
        }
        if off != self.weights.total_f32 {
            return Err(anyhow!("weights total {} != sum of params {off}", self.weights.total_f32));
        }
        if self.model.n_heads % self.model.n_kv_heads != 0 {
            return Err(anyhow!("GQA head counts inconsistent"));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Plan persistence: SparsePlan coordinates in the runtime manifest
// ---------------------------------------------------------------------------

/// `plan_store` schema version; bump on incompatible layout changes.
/// Stores written by a different version are rejected, never reinterpreted.
pub const PLAN_STORE_VERSION: usize = 1;

/// Key a persisted plan is filed under — ROADMAP's `(model, layer,
/// head_group, n)`: the session's in-memory `PlanCache` key widened by a
/// caller-chosen model identifier and the sequence length the coordinates
/// were built for.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanStoreKey {
    pub model: String,
    pub layer: u32,
    pub head_group: u32,
    pub n: usize,
}

/// One resident plan plus its LRU bookkeeping.
struct StoreEntry {
    /// Head dim the plan's `predicted_cost` was priced for.
    d: usize,
    plan: Arc<SparsePlan>,
    /// Logical timestamp of the last warm (`plans_for`) or `insert` touch;
    /// the eviction cap removes the lowest-stamped entry first.
    touched: u64,
}

/// Process-wide flush serialization, one lock per store path: concurrent
/// `PlanStore` instances on one manifest (shard coordinators, parallel
/// test sessions) must not interleave the read-merge-write in `flush`, or
/// the last writer would erase the others' entries. The key is the
/// canonicalized path, so `reports/m.json`, `./reports/m.json` and a
/// symlink to either all share one lock (the file exists — `open`
/// required it — so canonicalization only fails on races, where the raw
/// path is the best remaining key).
fn flush_lock(path: &Path) -> Arc<Mutex<()>> {
    static LOCKS: OnceLock<Mutex<HashMap<PathBuf, Arc<Mutex<()>>>>> = OnceLock::new();
    let key = std::fs::canonicalize(path).unwrap_or_else(|_| path.to_path_buf());
    let registry = LOCKS.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = registry.lock().unwrap_or_else(|e| e.into_inner());
    map.entry(key).or_default().clone()
}

/// Manifest-backed persistence for [`SparsePlan`] coordinates.
///
/// Plans live under a `plan_store` key *inside* an existing runtime
/// manifest JSON (the store never creates the manifest — a persistence
/// path without one is a configuration error surfaced at session build).
/// Only coordinates and identification provenance are stored;
/// `predicted_cost` is re-derived from the coordinates on load, and any
/// corrupted or truncated entry fails `open` with a descriptive error —
/// never a silent empty plan (DESIGN.md §11).
///
/// `flush` rewrites the document captured at `open` with the `plan_store`
/// key replaced, preserving every other manifest key. The write is a
/// *union*, built under a process-wide per-path lock: this store's
/// resident entries win per key, and on-disk entries another store
/// instance flushed since `open` are written through untouched — so
/// concurrent sessions persisting to one manifest never erase each
/// other's plans (DESIGN.md §12). Disk entries never enter this
/// instance's resident set, and keys this instance *evicted* are
/// tombstoned out of the union (an eviction is a real deletion, not a
/// suggestion the next flush resurrects).
///
/// An optional `max_entries` cap bounds the resident set LRU-ish: every
/// eviction is logged loudly, `plans_for` (the warm path) refreshes the
/// entries it serves, and `insert` never evicts the entry it just wrote.
pub struct PlanStore {
    path: PathBuf,
    doc: Json,
    entries: HashMap<PlanStoreKey, StoreEntry>,
    dirty: bool,
    /// LRU clock; bumped by `insert` and per `plans_for` warm pass.
    clock: u64,
    max_entries: Option<usize>,
    evictions: u64,
    /// Keys the cap evicted; excluded from the flush union so they stay
    /// deleted on disk (a later `insert` of the key clears the tombstone).
    evicted: HashSet<PlanStoreKey>,
}

impl PlanStore {
    /// Open the store inside the runtime manifest at `path`. The file must
    /// exist and hold a JSON object; a `plan_store` key, when present, is
    /// parsed strictly.
    pub fn open(path: impl Into<PathBuf>) -> Result<Self> {
        let path = path.into();
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow!(
                "plan store {}: persistence path has no runtime manifest ({e}); \
                 plans persist into an existing manifest JSON, e.g. artifacts/manifest.json",
                path.display()
            )
        })?;
        let doc = Json::parse(&text).map_err(|e| {
            anyhow!("plan store {}: manifest is not valid JSON: {e}", path.display())
        })?;
        if doc.as_obj().is_none() {
            return Err(anyhow!("plan store {}: manifest must be a JSON object", path.display()));
        }
        let mut entries = HashMap::new();
        let ps = doc.get("plan_store");
        if !ps.is_null() {
            let version = ps
                .get("version")
                .as_usize()
                .ok_or_else(|| anyhow!("plan store {}: missing version", path.display()))?;
            if version != PLAN_STORE_VERSION {
                return Err(anyhow!(
                    "plan store {}: unsupported version {version} (expected {PLAN_STORE_VERSION})",
                    path.display()
                ));
            }
            let arr = ps.get("entries").as_arr().ok_or_else(|| {
                anyhow!("plan store {}: entries must be an array", path.display())
            })?;
            for (i, e) in arr.iter().enumerate() {
                let (key, d, plan) = entry_from_json(e)
                    .with_context(|| format!("plan store {} entry {i}", path.display()))?;
                let entry = StoreEntry { d, plan: Arc::new(plan), touched: 0 };
                if entries.insert(key, entry).is_some() {
                    return Err(anyhow!("plan store {} entry {i}: duplicate key", path.display()));
                }
            }
        }
        Ok(Self {
            path,
            doc,
            entries,
            dirty: false,
            clock: 0,
            max_entries: None,
            evictions: 0,
            evicted: HashSet::new(),
        })
    }

    /// Cap the resident entry set (LRU-ish eviction, logged loudly).
    /// `None` removes the cap. A cap below the current size evicts
    /// immediately.
    pub fn set_max_entries(&mut self, cap: Option<usize>) {
        self.max_entries = cap;
        self.enforce_cap(None);
    }

    pub fn max_entries(&self) -> Option<usize> {
        self.max_entries
    }

    /// Entries evicted by the `max_entries` cap over this store's lifetime.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Evict lowest-touch entries until the cap holds, never removing
    /// `protect` (the entry an `insert` just wrote). Every eviction is
    /// loud: a silently shrinking store would masquerade as a cold cache.
    fn enforce_cap(&mut self, protect: Option<&PlanStoreKey>) {
        let Some(cap) = self.max_entries else { return };
        let cap = cap.max(1);
        while self.entries.len() > cap {
            let victim: Option<PlanStoreKey> = self
                .entries
                .iter()
                .filter(|&(k, _)| match protect {
                    Some(p) => p != k,
                    None => true,
                })
                .min_by(|a, b| {
                    (a.1.touched, &a.0.model, a.0.layer, a.0.head_group, a.0.n)
                        .cmp(&(b.1.touched, &b.0.model, b.0.layer, b.0.head_group, b.0.n))
                })
                .map(|(k, _)| k.clone());
            let Some(victim) = victim else { break };
            eprintln!(
                "plan store {}: max_entries={cap} exceeded, evicting \
                 (model={}, layer={}, head_group={}, n={})",
                self.path.display(),
                victim.model,
                victim.layer,
                victim.head_group,
                victim.n
            );
            self.entries.remove(&victim);
            self.evicted.insert(victim);
            self.evictions += 1;
            self.dirty = true;
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Look up one persisted plan (read-only peek; does not refresh the
    /// entry's eviction stamp — warming goes through [`PlanStore::plans_for`]).
    pub fn get(&self, key: &PlanStoreKey) -> Option<Arc<SparsePlan>> {
        self.entries.get(key).map(|e| e.plan.clone())
    }

    /// All plans stored for `(model, n)` as `(PlanKey, priced head dim,
    /// plan)` triples — the shape a session seeds its `PlanCache` from,
    /// in deterministic `(layer, head_group)` order. The head dim rides
    /// along because `predicted_cost` was derived with it; a session must
    /// reject entries priced for a different `d`. Served entries are
    /// touched (one shared stamp per warm pass), so the eviction cap
    /// removes cold entries before the ones a session just warmed from.
    pub fn plans_for(&mut self, model: &str, n: usize) -> Vec<(PlanKey, usize, Arc<SparsePlan>)> {
        self.clock += 1;
        let stamp = self.clock;
        let mut out: Vec<(PlanKey, usize, Arc<SparsePlan>)> = Vec::new();
        for (k, e) in self.entries.iter_mut() {
            if k.model == model && k.n == n {
                e.touched = stamp;
                out.push((PlanKey::new(k.layer, k.head_group), e.d, e.plan.clone()));
            }
        }
        out.sort_by_key(|(k, _, _)| (k.layer, k.head_group));
        out
    }

    /// Entries filed under `model` (any layer/head_group/length).
    pub fn len_for_model(&self, model: &str) -> usize {
        self.entries.keys().filter(|k| k.model == model).count()
    }

    /// Entries under `model` whose plan a `(method, tile, step)` session
    /// configuration could actually seed from (any length) — the same
    /// compatibility filter sessions apply when warming, so warm-start
    /// expectations (e.g. the serve plan-hit prior) read this, not a raw
    /// count.
    pub fn len_compatible(
        &self,
        model: &str,
        method: &str,
        tile: TileConfig,
        step: usize,
    ) -> usize {
        self.entries
            .iter()
            .filter(|(k, e)| {
                k.model == model
                    && e.plan.method == method
                    && e.plan.tile == tile
                    && e.plan.step == step
            })
            .count()
    }

    /// Insert or overwrite a plan (priced at head dim `d`); returns whether
    /// the store changed. Re-inserting the same plan is a no-op, detected
    /// by `Arc` identity first (the steady-state path: a session syncs the
    /// same cached `Arc`s every run) and deep equality otherwise, so
    /// steady-state serving never dirties the store.
    pub fn insert(&mut self, key: PlanStoreKey, d: usize, plan: Arc<SparsePlan>) -> bool {
        if let Some(e) = self.entries.get(&key) {
            if e.d == d && (Arc::ptr_eq(&e.plan, &plan) || *e.plan == *plan) {
                return false;
            }
        }
        self.clock += 1;
        let touched = self.clock;
        self.evicted.remove(&key);
        self.entries.insert(key.clone(), StoreEntry { d, plan, touched });
        self.dirty = true;
        self.enforce_cap(Some(&key));
        true
    }

    /// On-disk entries another store instance flushed since this one
    /// opened, minus keys resident here (ours win) or tombstoned by the
    /// cap (evictions stay deleted). Callers hold the per-path flush
    /// lock. Unparseable disk state yields nothing — the rewrite about to
    /// happen restores a valid store either way.
    fn disk_only_entries(&self) -> Vec<(PlanStoreKey, usize, Arc<SparsePlan>)> {
        let mut out = Vec::new();
        let Ok(text) = std::fs::read_to_string(&self.path) else { return out };
        let Ok(doc) = Json::parse(&text) else { return out };
        let ps = doc.get("plan_store");
        if ps.is_null() || ps.get("version").as_usize() != Some(PLAN_STORE_VERSION) {
            return out;
        }
        let Some(arr) = ps.get("entries").as_arr() else { return out };
        for e in arr {
            if let Ok((key, d, plan)) = entry_from_json(e) {
                if !self.entries.contains_key(&key) && !self.evicted.contains(&key) {
                    out.push((key, d, Arc::new(plan)));
                }
            }
        }
        out
    }

    /// Serialize the entries back into the manifest document and write it.
    /// A clean store is a no-op. Concurrent flushes to one path are
    /// serialized process-wide and the written set is the union of this
    /// store's residents with the disk-only entries of other instances
    /// (see the type docs), so a flush never erases entries another store
    /// instance committed first — and the cap never evicts them either
    /// (it bounds only this instance's resident set).
    pub fn flush(&mut self) -> Result<()> {
        if !self.dirty {
            return Ok(());
        }
        let lock = flush_lock(&self.path);
        let _guard = lock.lock().unwrap_or_else(|e| e.into_inner());
        let mut all: Vec<(PlanStoreKey, usize, Arc<SparsePlan>)> = self
            .entries
            .iter()
            .map(|(k, e)| (k.clone(), e.d, e.plan.clone()))
            .collect();
        all.extend(self.disk_only_entries());
        all.sort_by(|a, b| {
            (&a.0.model, a.0.layer, a.0.head_group, a.0.n)
                .cmp(&(&b.0.model, b.0.layer, b.0.head_group, b.0.n))
        });
        let entries: Vec<Json> =
            all.iter().map(|(k, d, plan)| entry_to_json(k, *d, plan)).collect();
        let ps = Json::obj(vec![
            ("version", Json::num(PLAN_STORE_VERSION as f64)),
            ("entries", Json::Arr(entries)),
        ]);
        if let Json::Obj(m) = &mut self.doc {
            m.insert("plan_store".to_string(), ps);
        }
        let mut text = self.doc.to_string_pretty();
        text.push('\n');
        // Write-then-rename: flush also runs best-effort from session
        // drop, and a crash mid-write must never destroy the manifest
        // (it holds the aot.py artifact contract, not just plans). The
        // temp name is unique per flush so two stores flushing one path
        // never clobber each other's in-flight write.
        static FLUSH_SEQ: AtomicU64 = AtomicU64::new(0);
        let seq = FLUSH_SEQ.fetch_add(1, Ordering::Relaxed);
        let mut tmp_name = self.path.as_os_str().to_os_string();
        tmp_name.push(format!(".tmp.{}.{seq}", std::process::id()));
        let tmp = PathBuf::from(tmp_name);
        std::fs::write(&tmp, &text)
            .with_context(|| format!("writing plan store {}", tmp.display()))?;
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("committing plan store {}", self.path.display()))?;
        self.dirty = false;
        // The committed file now reflects the deletions, so the
        // tombstones have done their one job. Keeping them would turn an
        // eviction into a permanent ban: another instance legitimately
        // re-writing the key later would be silently erased by this
        // instance's next flush.
        self.evicted.clear();
        Ok(())
    }
}

/// Method-name interning: `SparsePlan::method` is a `&'static str`, so a
/// deserialized plan (from the plan store or off the wire) must map onto a
/// known method identifier — an unknown name is a corruption signal, never
/// silently accepted.
pub(crate) fn method_static(name: &str) -> Result<&'static str> {
    const KNOWN: [&str; 7] = [
        "full-attn",
        "anchor",
        "streaming-llm",
        "vertical-slash",
        "flexprefill",
        "block-topk",
        "test",
    ];
    KNOWN
        .iter()
        .find(|&&k| k == name)
        .copied()
        .ok_or_else(|| anyhow!("unknown method '{name}' in plan store"))
}

fn cost_to_json(c: &CostTally) -> Json {
    Json::obj(vec![
        ("flops", Json::num(c.flops as f64)),
        ("kv_bytes", Json::num(c.kv_bytes as f64)),
        ("ident_scores", Json::num(c.ident_scores as f64)),
    ])
}

fn cost_from_json(j: &Json) -> Result<CostTally> {
    let field = |k: &str| -> Result<u64> {
        let x = j.get(k).as_f64().ok_or_else(|| anyhow!("cost missing {k}"))?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(anyhow!("cost {k} is not a non-negative integer"));
        }
        Ok(x as u64)
    };
    Ok(CostTally {
        flops: field("flops")?,
        kv_bytes: field("kv_bytes")?,
        ident_scores: field("ident_scores")?,
    })
}

/// Serialize a plan's coordinates plus its identification provenance.
/// `d` is the head dim the plan was priced for; `predicted_cost` is *not*
/// persisted — it is re-derived from the coordinates on load, so the
/// stored unit stays pure coordinates (DESIGN.md §11).
pub fn plan_to_json(plan: &SparsePlan, d: usize) -> Json {
    Json::obj(vec![
        ("method", Json::str(plan.method)),
        ("n", Json::num(plan.n as f64)),
        ("d", Json::num(d as f64)),
        ("b_q", Json::num(plan.tile.b_q as f64)),
        ("b_kv", Json::num(plan.tile.b_kv as f64)),
        ("step", Json::num(plan.step as f64)),
        ("ident_cost", cost_to_json(&plan.ident_cost)),
        (
            "groups",
            Json::arr(plan.groups.iter().map(|g| {
                Json::obj(vec![
                    (
                        "spans",
                        Json::arr(g.spans.iter().map(|&(s, e)| {
                            Json::arr([Json::num(s as f64), Json::num(e as f64)])
                        })),
                    ),
                    ("stripes", Json::arr(g.stripes.iter().map(|&c| Json::num(c as f64)))),
                ])
            })),
        ),
    ])
}

/// Deserialize a plan, validating every coordinate: sizes nonzero, group
/// count matching `(n, b_q, step)`, spans sorted/in-range/non-overlapping,
/// stripes strictly ascending and `< n`. Returns the plan and the head dim
/// it was priced for; `predicted_cost` is recomputed, not trusted.
pub fn plan_from_json(j: &Json) -> Result<(SparsePlan, usize)> {
    let method = method_static(
        j.get("method").as_str().ok_or_else(|| anyhow!("plan missing method"))?,
    )?;
    let req = |k: &str| -> Result<usize> {
        j.get(k).as_usize().ok_or_else(|| anyhow!("plan missing {k}"))
    };
    let n = req("n")?;
    let d = req("d")?;
    let b_q = req("b_q")?;
    let b_kv = req("b_kv")?;
    let step = req("step")?;
    if n == 0 || d == 0 || b_q == 0 || b_kv == 0 || step == 0 {
        return Err(anyhow!("plan has a zero-sized dimension"));
    }
    if n > u32::MAX as usize {
        return Err(anyhow!("plan n={n} exceeds the u32 coordinate range"));
    }
    let tile = TileConfig::new(b_q, b_kv);
    let ident_cost = cost_from_json(j.get("ident_cost"))?;
    let garr = j.get("groups").as_arr().ok_or_else(|| anyhow!("plan missing groups"))?;
    let expect_groups = tile.q_blocks(n).div_ceil(step);
    if garr.len() != expect_groups {
        return Err(anyhow!(
            "plan has {} groups, expected {expect_groups} for n={n}, b_q={b_q}, step={step}",
            garr.len()
        ));
    }
    let mut groups = Vec::with_capacity(garr.len());
    for (gi, g) in garr.iter().enumerate() {
        let spans_arr =
            g.get("spans").as_arr().ok_or_else(|| anyhow!("group {gi}: missing spans"))?;
        let mut spans = Vec::with_capacity(spans_arr.len());
        let mut prev_end = 0usize;
        for (si, pair) in spans_arr.iter().enumerate() {
            let s =
                pair.idx(0).as_usize().ok_or_else(|| anyhow!("group {gi} span {si}: bad start"))?;
            let e =
                pair.idx(1).as_usize().ok_or_else(|| anyhow!("group {gi} span {si}: bad end"))?;
            if s >= e || e > n {
                return Err(anyhow!("group {gi} span {si}: [{s}, {e}) out of range for n={n}"));
            }
            if si > 0 && s < prev_end {
                return Err(anyhow!("group {gi} span {si}: overlapping or unsorted spans"));
            }
            prev_end = e;
            spans.push((s as u32, e as u32));
        }
        let stripes_arr =
            g.get("stripes").as_arr().ok_or_else(|| anyhow!("group {gi}: missing stripes"))?;
        let mut stripes: Vec<u32> = Vec::with_capacity(stripes_arr.len());
        for (ci, c) in stripes_arr.iter().enumerate() {
            let col = c.as_usize().ok_or_else(|| anyhow!("group {gi} stripe {ci}: bad column"))?;
            if col >= n {
                return Err(anyhow!("group {gi} stripe {ci}: column {col} >= n={n}"));
            }
            if let Some(&last) = stripes.last() {
                if col as u32 <= last {
                    return Err(anyhow!(
                        "group {gi} stripe {ci}: unsorted or duplicate column {col}"
                    ));
                }
            }
            stripes.push(col as u32);
        }
        groups.push(GroupPlan { spans, stripes });
    }
    Ok((SparsePlan::new(method, n, d, tile, step, groups, ident_cost), d))
}

// ---------------------------------------------------------------------------
// Calibration: measured cost constants in the runtime manifest
// ---------------------------------------------------------------------------

/// `calibration` schema version; bump on incompatible layout changes.
/// Entries written by a different version are rejected, never
/// reinterpreted.
pub const CALIBRATION_VERSION: usize = 1;

fn constants_to_json(c: &CostConstants) -> Json {
    Json::obj(vec![
        ("ident_cost_frac", Json::num(c.ident_cost_frac)),
        ("plan_broadcast_frac", Json::num(c.plan_broadcast_frac)),
        ("span_ns_per_row", Json::num(c.span_ns_per_row)),
        ("gather_ns_per_row", Json::num(c.gather_ns_per_row)),
        ("fold_ns_per_score", Json::num(c.fold_ns_per_score)),
    ])
}

fn constants_from_json(j: &Json) -> Result<CostConstants> {
    let field = |k: &str| -> Result<f64> {
        let x = j.get(k).as_f64().ok_or_else(|| anyhow!("calibration missing {k}"))?;
        if !x.is_finite() || x < 0.0 {
            return Err(anyhow!("calibration {k} must be a finite non-negative number"));
        }
        Ok(x)
    };
    Ok(CostConstants {
        ident_cost_frac: field("ident_cost_frac")?,
        plan_broadcast_frac: field("plan_broadcast_frac")?,
        span_ns_per_row: field("span_ns_per_row")?,
        gather_ns_per_row: field("gather_ns_per_row")?,
        fold_ns_per_score: field("fold_ns_per_score")?,
    })
}

/// Persist one executor's measured [`CostConstants`] under the manifest's
/// `calibration` key, preserving every other key — including other
/// executors' entries — with the plan store's write-then-rename
/// discipline. The file must already exist and hold a JSON object:
/// calibration rides in a runtime manifest, it never creates one.
pub fn save_calibration(
    path: impl AsRef<Path>,
    kind: ExecutorKind,
    c: &CostConstants,
) -> Result<()> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path).map_err(|e| {
        anyhow!(
            "calibration {}: persistence path has no runtime manifest ({e}); \
             constants persist into an existing manifest JSON, e.g. artifacts/manifest.json",
            path.display()
        )
    })?;
    let mut doc = Json::parse(&text)
        .map_err(|e| anyhow!("calibration {}: manifest is not valid JSON: {e}", path.display()))?;
    if doc.as_obj().is_none() {
        return Err(anyhow!("calibration {}: manifest must be a JSON object", path.display()));
    }
    // Merge into the existing executors map so calibrating one backend
    // never drops the other's constants.
    let mut executors: Vec<(String, Json)> = Vec::new();
    let existing = doc.get("calibration");
    if !existing.is_null() && existing.get("version").as_usize() == Some(CALIBRATION_VERSION) {
        if let Some(map) = existing.get("executors").as_obj() {
            for (k, v) in map {
                if k != kind.name() {
                    executors.push((k.clone(), v.clone()));
                }
            }
        }
    }
    executors.push((kind.name().to_string(), constants_to_json(c)));
    let cal = Json::obj(vec![
        ("version", Json::num(CALIBRATION_VERSION as f64)),
        ("executors", Json::Obj(executors.into_iter().collect())),
    ]);
    if let Json::Obj(m) = &mut doc {
        m.insert("calibration".to_string(), cal);
    }
    let mut out = doc.to_string_pretty();
    out.push('\n');
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(format!(".cal.tmp.{}", std::process::id()));
    let tmp = PathBuf::from(tmp_name);
    std::fs::write(&tmp, &out)
        .with_context(|| format!("writing calibration {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("committing calibration {}", path.display()))?;
    Ok(())
}

/// Load the constants calibrated for `kind`, if the manifest carries any.
/// `Ok(None)` means "never calibrated" (no `calibration` key, or no entry
/// for this executor); a malformed or version-mismatched key is an `Err`,
/// never silently the modeled defaults.
pub fn load_calibration(
    path: impl AsRef<Path>,
    kind: ExecutorKind,
) -> Result<Option<CostConstants>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("calibration {}: {e}", path.display()))?;
    let doc = Json::parse(&text)
        .map_err(|e| anyhow!("calibration {}: manifest is not valid JSON: {e}", path.display()))?;
    let cal = doc.get("calibration");
    if cal.is_null() {
        return Ok(None);
    }
    let version = cal
        .get("version")
        .as_usize()
        .ok_or_else(|| anyhow!("calibration {}: missing version", path.display()))?;
    if version != CALIBRATION_VERSION {
        return Err(anyhow!(
            "calibration {}: unsupported version {version} (expected {CALIBRATION_VERSION})",
            path.display()
        ));
    }
    let entry = cal.get("executors").get(kind.name());
    if entry.is_null() {
        return Ok(None);
    }
    constants_from_json(entry)
        .with_context(|| format!("calibration {} executor {}", path.display(), kind.name()))
        .map(Some)
}

fn entry_to_json(key: &PlanStoreKey, d: usize, plan: &SparsePlan) -> Json {
    Json::obj(vec![
        ("model", Json::str(&key.model)),
        ("layer", Json::num(key.layer as f64)),
        ("head_group", Json::num(key.head_group as f64)),
        ("n", Json::num(key.n as f64)),
        ("plan", plan_to_json(plan, d)),
    ])
}

fn entry_from_json(j: &Json) -> Result<(PlanStoreKey, usize, SparsePlan)> {
    let model = j.get("model").as_str().ok_or_else(|| anyhow!("entry missing model"))?.to_string();
    let layer = j.get("layer").as_usize().ok_or_else(|| anyhow!("entry missing layer"))? as u32;
    let head_group =
        j.get("head_group").as_usize().ok_or_else(|| anyhow!("entry missing head_group"))? as u32;
    let n = j.get("n").as_usize().ok_or_else(|| anyhow!("entry missing n"))?;
    let (plan, d) = plan_from_json(j.get("plan"))?;
    if plan.n != n {
        return Err(anyhow!("entry n={n} disagrees with plan n={}", plan.n));
    }
    Ok((PlanStoreKey { model, layer, head_group, n }, d, plan))
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
        "model": {"vocab": 512, "d_model": 256, "n_layers": 4, "n_heads": 8,
                  "n_kv_heads": 4, "d_head": 32, "d_ffn": 512, "max_seq": 2048,
                  "prefill_chunk": 256},
        "anchor": {"block": 32, "theta": 12.0, "step": 4, "init_blocks": 1},
        "weights": {"file": "weights.bin", "total_f32": 12,
                    "params": [{"name": "a", "shape": [3, 2], "offset": 0, "count": 6},
                               {"name": "b", "shape": [6], "offset": 6, "count": 6}]},
        "artifacts": [{"name": "x", "file": "x.hlo.txt",
                       "inputs": [{"dtype": "f32", "shape": [4, 4]}],
                       "outputs": [{"dtype": "f32", "shape": [4]}]}]
    }"#;

    #[test]
    fn parse_and_validate_mini() {
        let m = Manifest::parse(MINI).unwrap();
        m.validate().unwrap();
        assert_eq!(m.model.vocab, 512);
        assert_eq!(m.anchor.step, 4);
        assert_eq!(m.weights.params.len(), 2);
        let a = m.artifact("x").unwrap();
        assert_eq!(a.inputs[0].shape, vec![4, 4]);
        assert_eq!(a.inputs[0].elements(), 16);
        assert!(m.artifact("nope").is_none());
    }

    #[test]
    fn validate_rejects_bad_offsets() {
        let bad = MINI.replace("\"offset\": 6", "\"offset\": 7");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_total() {
        let bad = MINI.replace("\"total_f32\": 12", "\"total_f32\": 13");
        let m = Manifest::parse(&bad).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn parse_rejects_missing_model_field() {
        let bad = MINI.replace("\"vocab\": 512, ", "");
        assert!(Manifest::parse(&bad).is_err());
    }

    // ---- plan store -------------------------------------------------------

    fn tmp_manifest(tag: &str, contents: &str) -> PathBuf {
        let path = std::env::temp_dir()
            .join(format!("anchor_manifest_{}_{tag}.json", std::process::id()));
        std::fs::write(&path, contents).unwrap();
        path
    }

    fn sample_plan(n: usize, d: usize) -> SparsePlan {
        let tile = TileConfig::new(16, 16);
        let groups: Vec<GroupPlan> = (0..tile.q_blocks(n).div_ceil(2))
            .map(|g| {
                let win = (g * 2 * 16) as u32;
                let end = ((g + 1) * 2 * 16).min(n) as u32;
                if win == 0 {
                    GroupPlan { spans: vec![(0, end)], stripes: vec![] }
                } else {
                    GroupPlan {
                        spans: vec![(0, 16), (win, end)],
                        stripes: (16..win).step_by(5).collect(),
                    }
                }
            })
            .collect();
        let ident = CostTally { flops: 640, kv_bytes: 128, ident_scores: 32 };
        SparsePlan::new("anchor", n, d, tile, 2, groups, ident)
    }

    #[test]
    fn plan_json_round_trips_identically() {
        let plan = sample_plan(96, 8);
        let j = plan_to_json(&plan, 8);
        let reparsed = Json::parse(&j.to_string()).unwrap();
        let (back, d) = plan_from_json(&reparsed).unwrap();
        assert_eq!(d, 8);
        assert_eq!(back, plan, "round trip must be identity, predicted cost included");
    }

    #[test]
    fn plan_store_round_trips_through_the_manifest_file() {
        let path = tmp_manifest("roundtrip", "{\"other_key\": 7}\n");
        let plan = Arc::new(sample_plan(96, 8));
        let key = PlanStoreKey { model: "m".into(), layer: 0, head_group: 1, n: 96 };
        let mut store = PlanStore::open(&path).unwrap();
        assert!(store.is_empty());
        assert!(store.insert(key.clone(), 8, plan.clone()));
        // Re-inserting the identical plan does not dirty the store.
        assert!(!store.insert(key.clone(), 8, plan.clone()));
        store.flush().unwrap();

        let mut reopened = PlanStore::open(&path).unwrap();
        assert_eq!(reopened.len(), 1);
        assert_eq!(*reopened.get(&key).unwrap(), *plan);
        let seeds = reopened.plans_for("m", 96);
        assert_eq!(seeds.len(), 1);
        assert_eq!(seeds[0].0, PlanKey::new(0, 1));
        assert_eq!(seeds[0].1, 8, "priced head dim rides along");
        assert!(reopened.plans_for("m", 128).is_empty());
        assert!(reopened.plans_for("other", 96).is_empty());
        assert_eq!(reopened.len_for_model("m"), 1);
        assert_eq!(reopened.len_compatible("m", "anchor", TileConfig::new(16, 16), 2), 1);
        assert_eq!(reopened.len_compatible("m", "anchor", TileConfig::new(16, 16), 4), 0);
        assert_eq!(reopened.len_compatible("m", "full-attn", TileConfig::new(16, 16), 2), 0);
        // Other manifest keys survive the rewrite.
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("other_key").as_usize(), Some(7));
        assert_eq!(doc.get("plan_store").get("version").as_usize(), Some(1));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn plan_store_requires_an_existing_manifest() {
        let missing = std::env::temp_dir().join("anchor_manifest_does_not_exist.json");
        let err = PlanStore::open(&missing).unwrap_err().to_string();
        assert!(err.contains("no runtime manifest"), "{err}");
        let not_obj = tmp_manifest("not_obj", "[1, 2]\n");
        assert!(PlanStore::open(&not_obj).is_err());
        let _ = std::fs::remove_file(&not_obj);
    }

    #[test]
    fn corrupted_store_entries_are_rejected_not_emptied() {
        let path = tmp_manifest("corrupt", "{}\n");
        let mut store = PlanStore::open(&path).unwrap();
        store.insert(
            PlanStoreKey { model: "m".into(), layer: 0, head_group: 0, n: 96 },
            8,
            Arc::new(sample_plan(96, 8)),
        );
        store.flush().unwrap();
        let good = std::fs::read_to_string(&path).unwrap();

        // Truncated file: not JSON at all.
        std::fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert!(PlanStore::open(&path).is_err());

        // Structurally valid JSON, corrupted plan fields: each must error.
        for (from, to) in [
            ("\"step\": 2", "\"step\": 0"),
            ("\"method\": \"anchor\"", "\"method\": \"mystery\""),
            ("\"n\": 96", "\"n\": 95"),
            ("\"version\": 1", "\"version\": 99"),
        ] {
            assert!(good.contains(from), "fixture drifted: {from}");
            std::fs::write(&path, good.replace(from, to)).unwrap();
            let err = PlanStore::open(&path).unwrap_err().to_string();
            assert!(!err.is_empty(), "{from} -> {to} must be rejected");
        }

        // The pristine store still reopens after the corruption sweep.
        std::fs::write(&path, &good).unwrap();
        assert!(PlanStore::open(&path).is_ok(), "pristine store must reopen");
        let _ = std::fs::remove_file(&path);
    }

    fn key(model: &str, group: u32, n: usize) -> PlanStoreKey {
        PlanStoreKey { model: model.into(), layer: 0, head_group: group, n }
    }

    /// Calibration constants round-trip per executor through the manifest:
    /// saving one backend preserves the other's entry and every unrelated
    /// manifest key, and corruption is an error, never silent defaults.
    #[test]
    fn calibration_round_trips_per_executor_and_preserves_keys() {
        let path = tmp_manifest("calibration", "{\"other_key\": 7}\n");
        assert_eq!(load_calibration(&path, ExecutorKind::Cpu).unwrap(), None);

        let cpu = CostConstants {
            ident_cost_frac: 0.2,
            plan_broadcast_frac: 0.003,
            span_ns_per_row: 1.5,
            gather_ns_per_row: 6.25,
            fold_ns_per_score: 0.75,
        };
        let pjrt = CostConstants { ident_cost_frac: 0.3, ..cpu };
        save_calibration(&path, ExecutorKind::Cpu, &cpu).unwrap();
        save_calibration(&path, ExecutorKind::Pjrt, &pjrt).unwrap();
        assert_eq!(load_calibration(&path, ExecutorKind::Cpu).unwrap(), Some(cpu));
        assert_eq!(load_calibration(&path, ExecutorKind::Pjrt).unwrap(), Some(pjrt));

        // Re-saving one backend keeps the other and the unrelated keys.
        let cpu2 = CostConstants { fold_ns_per_score: 0.5, ..cpu };
        save_calibration(&path, ExecutorKind::Cpu, &cpu2).unwrap();
        assert_eq!(load_calibration(&path, ExecutorKind::Cpu).unwrap(), Some(cpu2));
        assert_eq!(load_calibration(&path, ExecutorKind::Pjrt).unwrap(), Some(pjrt));
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("other_key").as_usize(), Some(7));
        assert_eq!(doc.get("calibration").get("version").as_usize(), Some(1));

        // Corrupted entries and version drift are rejected loudly.
        let good = std::fs::read_to_string(&path).unwrap();
        for (from, to) in [
            ("\"version\": 1", "\"version\": 99"),
            ("\"ident_cost_frac\": 0.2", "\"ident_cost_frac\": \"fast\""),
        ] {
            assert!(good.contains(from), "fixture drifted: {from}");
            std::fs::write(&path, good.replace(from, to)).unwrap();
            assert!(load_calibration(&path, ExecutorKind::Cpu).is_err(), "{from} -> {to}");
        }
        // Saving never creates a manifest from nothing.
        let missing = std::env::temp_dir().join("anchor_manifest_cal_missing.json");
        let _ = std::fs::remove_file(&missing);
        assert!(save_calibration(&missing, ExecutorKind::Cpu, &cpu).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn max_entries_cap_evicts_lru_and_counts() {
        let path = tmp_manifest("cap_lru", "{}\n");
        let mut store = PlanStore::open(&path).unwrap();
        store.set_max_entries(Some(2));
        assert_eq!(store.max_entries(), Some(2));
        let plan = Arc::new(sample_plan(96, 8));
        store.insert(key("m", 0, 96), 8, plan.clone());
        store.insert(key("m", 1, 96), 8, plan.clone());
        assert_eq!((store.len(), store.evictions()), (2, 0));
        // Third insert overflows: the oldest-touched entry (group 0) goes,
        // never the entry just written.
        store.insert(key("m", 2, 96), 8, plan.clone());
        assert_eq!((store.len(), store.evictions()), (2, 1));
        assert!(store.get(&key("m", 0, 96)).is_none(), "LRU entry must evict");
        assert!(store.get(&key("m", 2, 96)).is_some(), "just-inserted entry survives");
        // Re-inserting an identical resident plan is a no-op, no eviction.
        assert!(!store.insert(key("m", 2, 96), 8, plan.clone()));
        assert_eq!(store.evictions(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn warm_pass_protects_seeded_entries_from_eviction() {
        let path = tmp_manifest("cap_warm", "{}\n");
        let plan96 = Arc::new(sample_plan(96, 8));
        let plan128 = Arc::new(sample_plan(128, 8));
        let mut store = PlanStore::open(&path).unwrap();
        // Cold entry at n=128, then the n=96 entry a session will warm from.
        store.insert(key("m", 0, 128), 8, plan128);
        store.insert(key("m", 0, 96), 8, plan96.clone());
        store.set_max_entries(Some(2));
        // Warm pass: seeding touches the n=96 entry...
        let seeds = store.plans_for("m", 96);
        assert_eq!(seeds.len(), 1);
        // ...so the next insert evicts the cold n=128 entry, never the one
        // the session just warmed from.
        store.insert(key("m", 1, 96), 8, plan96);
        assert_eq!(store.len(), 2);
        assert!(store.get(&key("m", 0, 96)).is_some(), "warmed entry must survive");
        assert!(store.get(&key("m", 0, 128)).is_none(), "cold entry evicts instead");
        assert_eq!(store.evictions(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cap_below_current_size_evicts_immediately_and_flushes() {
        let path = tmp_manifest("cap_shrink", "{}\n");
        let plan = Arc::new(sample_plan(96, 8));
        let mut store = PlanStore::open(&path).unwrap();
        for g in 0..4 {
            store.insert(key("m", g, 96), 8, plan.clone());
        }
        store.flush().unwrap();
        store.set_max_entries(Some(2));
        assert_eq!(store.len(), 2);
        assert_eq!(store.evictions(), 2);
        store.flush().unwrap();
        // The capped set persists: evicted keys are tombstoned out of the
        // flush union, so the stale on-disk copies are really deleted —
        // never resurrected past the bound — and evictions() stays 2.
        assert_eq!(store.evictions(), 2, "flush must not re-evict");
        let reopened = PlanStore::open(&path).unwrap();
        assert_eq!(reopened.len(), 2, "flush after eviction persists the capped set");
        let _ = std::fs::remove_file(&path);
    }
}

//! PJRT runtime: load AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the CPU PJRT client.
//!
//! Python never runs on the request path — after `make artifacts` the Rust
//! binary is self-contained. Interchange is HLO **text** (see
//! DESIGN.md / aot.py header for the 64-bit-proto-id rationale).
//!
//! Thread-model note: `PjRtClient` is `Rc`-based (not `Send`), so a
//! [`Runtime`] must be owned by a single thread. The coordinator runs one
//! dedicated engine thread that owns the runtime (`coordinator::engine`).

pub mod manifest;
pub mod segment;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

pub use manifest::{ArtifactSpec, Manifest, TensorSpec};

/// Lazily-compiling artifact registry over a PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    executables: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, manifest, dir, executables: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact's executable.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.executables.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.executables.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact. Inputs must match the manifest spec; outputs
    /// are the decomposed result tuple (aot.py lowers with
    /// `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "artifact '{name}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Load the flat f32 weight blob as per-parameter Literals (the
    /// ordered prefix of every `lm_*` artifact's inputs).
    pub fn load_weights(&self) -> Result<Vec<xla::Literal>> {
        let w = &self.manifest.weights;
        let path = self.dir.join(&w.file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading weights {}", path.display()))?;
        if bytes.len() != w.total_f32 * 4 {
            return Err(anyhow!(
                "weights.bin is {} bytes, manifest says {}",
                bytes.len(),
                w.total_f32 * 4
            ));
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let mut out = Vec::with_capacity(w.params.len());
        for p in &w.params {
            let slice = &floats[p.offset..p.offset + p.count];
            let dims: Vec<i64> = p.shape.iter().map(|&x| x as i64).collect();
            out.push(xla::Literal::vec1(slice).reshape(&dims)?);
        }
        Ok(out)
    }
}

/// Build an f32 Literal from a shape + data.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let count: usize = shape.iter().product();
    if count != data.len() {
        return Err(anyhow!("shape {:?} needs {count} elements, got {}", shape, data.len()));
    }
    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 Literal (1-D).
pub fn literal_i32(data: &[i32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Scalar i32 Literal.
pub fn literal_i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

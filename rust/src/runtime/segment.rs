//! Binary segment files for the plan store (DESIGN.md §15).
//!
//! A segment is an immutable file of delta-encoded plan payloads
//! (`crate::plan_codec::put_plan` output) living in a sidecar directory
//! next to the runtime manifest (`<manifest>.segments/`). The manifest's
//! `plan_store` key holds the index — key → (segment, offset, len, crc)
//! plus a model/method/geometry summary — so seeding reads only the byte
//! ranges matching the session's filter.
//!
//! Layout discipline mirrors the wire frames (`wire/frame.rs`): a magic +
//! version header so foreign files are rejected before any decode, a
//! length prefix per entry so truncation is structurally detectable, and
//! a CRC32 per entry so bit-flips are rejected loudly instead of decoding
//! into a plausible-but-wrong plan. Segments are never modified in place:
//! every flush writes a *new* segment via write-then-rename, and
//! compaction replaces the whole set the same way — a crash at any byte
//! leaves either the old index valid or the new one committed.
//!
//! ```text
//! segment file:  [magic "ANKS" (4)] [version u16 LE] [reserved u16 = 0]
//!                then per entry: [len u32 LE] [crc32 u32 LE] [payload]
//! ```
//!
//! The index records `offset` = start of the entry frame and `len` =
//! payload length; readers re-verify both the frame fields and the
//! payload CRC against the index before handing bytes to the codec.

use std::fs;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use anyhow::{anyhow, Context, Result};

/// First bytes of every segment file ("ANKS" — anchor segment).
pub const SEGMENT_MAGIC: [u8; 4] = *b"ANKS";
/// Bumped on any layout change; readers reject other versions loudly.
pub const SEGMENT_VERSION: u16 = 1;
/// Magic (4) + version (2) + reserved (2).
pub const SEGMENT_HEADER_BYTES: u64 = 8;
/// Length prefix (4) + CRC32 (4) ahead of each payload.
pub const ENTRY_FRAME_BYTES: u64 = 8;
/// Sanity cap on a single plan payload — far above any real plan, small
/// enough that a corrupted index length cannot drive a giant allocation.
pub const MAX_ENTRY_BYTES: u32 = 64 << 20;

/// Where one entry's payload lives. `offset` points at the entry frame
/// (len + crc), not the payload itself.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SegmentLoc {
    pub segment: String,
    pub offset: u64,
    pub len: u32,
    pub crc: u32,
}

impl SegmentLoc {
    /// First byte past this entry — the minimum file length that can hold it.
    pub fn end(&self) -> u64 {
        self.offset + ENTRY_FRAME_BYTES + u64::from(self.len)
    }
}

/// Sidecar directory for a manifest path: `reports/plan_manifest.json`
/// keeps its segments in `reports/plan_manifest.json.segments/`.
pub fn segments_dir(manifest_path: &Path) -> PathBuf {
    let mut os = manifest_path.as_os_str().to_os_string();
    os.push(".segments");
    PathBuf::from(os)
}

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, reflected) — table-based, no external crates.
// ---------------------------------------------------------------------------

fn crc_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut table = [0u32; 256];
        for (i, slot) in table.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        table
    })
}

/// CRC-32/IEEE of `bytes` (`crc32(b"123456789") == 0xCBF4_3926`).
pub fn crc32(bytes: &[u8]) -> u32 {
    let table = crc_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ u32::from(b)) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Naming
// ---------------------------------------------------------------------------

/// Parse `seg-NNNNNN.bin` → `NNNNNN`. Temp files and foreign names → None.
pub fn segment_seq(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("seg-")?.strip_suffix(".bin")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Every plain file currently in the sidecar dir (segments, temps, strays).
/// A missing dir is an empty store, not an error.
pub fn list_files(dir: &Path) -> Result<Vec<String>> {
    let mut names = Vec::new();
    let rd = match fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(names),
        Err(e) => return Err(e).with_context(|| format!("listing {}", dir.display())),
    };
    for entry in rd {
        let entry = entry.with_context(|| format!("listing {}", dir.display()))?;
        if entry.file_type().map(|t| t.is_file()).unwrap_or(false) {
            if let Some(name) = entry.file_name().to_str() {
                names.push(name.to_string());
            }
        }
    }
    names.sort();
    Ok(names)
}

/// Next unused segment name: one past the highest `seg-NNNNNN.bin` on
/// disk. Scanning the dir (rather than counting index entries) means a
/// crashed writer's leftover file can never be silently overwritten.
pub fn next_segment_name(dir: &Path) -> Result<String> {
    let max = list_files(dir)?.iter().filter_map(|n| segment_seq(n)).max().unwrap_or(0);
    Ok(format!("seg-{:06}.bin", max + 1))
}

// ---------------------------------------------------------------------------
// Write / read
// ---------------------------------------------------------------------------

static SEGMENT_TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Write `payloads` into a brand-new segment `dir/name` (write-then-rename;
/// the file appears atomically or not at all). Returns one [`SegmentLoc`]
/// per payload, in order.
pub fn write_segment(dir: &Path, name: &str, payloads: &[&[u8]]) -> Result<Vec<SegmentLoc>> {
    fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let mut buf: Vec<u8> = Vec::with_capacity(
        SEGMENT_HEADER_BYTES as usize
            + payloads.iter().map(|p| p.len() + ENTRY_FRAME_BYTES as usize).sum::<usize>(),
    );
    buf.extend_from_slice(&SEGMENT_MAGIC);
    buf.extend_from_slice(&SEGMENT_VERSION.to_le_bytes());
    buf.extend_from_slice(&[0u8; 2]);
    let mut locs = Vec::with_capacity(payloads.len());
    for payload in payloads {
        if payload.is_empty() || payload.len() > MAX_ENTRY_BYTES as usize {
            return Err(anyhow!(
                "segment entry of {} bytes out of range 1..={MAX_ENTRY_BYTES}",
                payload.len()
            ));
        }
        let offset = buf.len() as u64;
        let crc = crc32(payload);
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&crc.to_le_bytes());
        buf.extend_from_slice(payload);
        locs.push(SegmentLoc { segment: name.to_string(), offset, len: payload.len() as u32, crc });
    }
    let tmp = dir.join(format!(
        "{name}.tmp.{}.{}",
        std::process::id(),
        SEGMENT_TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let path = dir.join(name);
    let write = (|| -> std::io::Result<()> {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(&buf)?;
        f.sync_all()?;
        fs::rename(&tmp, &path)
    })();
    if let Err(e) = write {
        let _ = fs::remove_file(&tmp);
        return Err(e).with_context(|| format!("writing segment {}", path.display()));
    }
    Ok(locs)
}

/// Validate a segment's header and that the file can hold `min_len`
/// bytes. Returns the file length. Called at store open with `min_len` =
/// the index's max entry end, so *any* truncation of an indexed range is
/// caught before a single payload is read.
pub fn check_segment(dir: &Path, name: &str, min_len: u64) -> Result<u64> {
    let path = dir.join(name);
    let mut f =
        fs::File::open(&path).with_context(|| format!("opening segment {}", path.display()))?;
    let file_len =
        f.metadata().with_context(|| format!("stat segment {}", path.display()))?.len();
    if file_len < SEGMENT_HEADER_BYTES {
        return Err(anyhow!(
            "segment {} is {file_len} bytes — shorter than its {SEGMENT_HEADER_BYTES}-byte header",
            path.display()
        ));
    }
    let mut header = [0u8; SEGMENT_HEADER_BYTES as usize];
    f.read_exact(&mut header)
        .with_context(|| format!("reading segment header {}", path.display()))?;
    if header[..4] != SEGMENT_MAGIC {
        return Err(anyhow!("segment {} has bad magic {:02x?}", path.display(), &header[..4]));
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != SEGMENT_VERSION {
        return Err(anyhow!(
            "segment {} is version {version}, expected {SEGMENT_VERSION}",
            path.display()
        ));
    }
    if file_len < min_len {
        return Err(anyhow!(
            "segment {} is {file_len} bytes but the index references {min_len} — truncated",
            path.display()
        ));
    }
    Ok(file_len)
}

/// Read and verify one entry's payload. Checks the header, the frame's
/// length and CRC fields against the index, and the payload CRC against
/// the frame — a mismatch anywhere is a loud `Err`, never a wrong plan.
pub fn read_payload(dir: &Path, loc: &SegmentLoc) -> Result<Vec<u8>> {
    if loc.len == 0 || loc.len > MAX_ENTRY_BYTES {
        return Err(anyhow!(
            "index length {} for {}@{} out of range 1..={MAX_ENTRY_BYTES}",
            loc.len,
            loc.segment,
            loc.offset
        ));
    }
    check_segment(dir, &loc.segment, loc.end())?;
    let path = dir.join(&loc.segment);
    let mut f =
        fs::File::open(&path).with_context(|| format!("opening segment {}", path.display()))?;
    f.seek(SeekFrom::Start(loc.offset))
        .with_context(|| format!("seeking {}@{}", path.display(), loc.offset))?;
    let mut frame = [0u8; ENTRY_FRAME_BYTES as usize];
    f.read_exact(&mut frame).with_context(|| format!("reading {}@{}", path.display(), loc.offset))?;
    let len = u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]);
    let crc = u32::from_le_bytes([frame[4], frame[5], frame[6], frame[7]]);
    if len != loc.len || crc != loc.crc {
        return Err(anyhow!(
            "segment {}@{}: frame says len={len} crc={crc:08x}, index says len={} crc={:08x}",
            path.display(),
            loc.offset,
            loc.len,
            loc.crc
        ));
    }
    let mut payload = vec![0u8; len as usize];
    f.read_exact(&mut payload)
        .with_context(|| format!("reading {} payload bytes at {}@{}", len, path.display(), loc.offset))?;
    let actual = crc32(&payload);
    if actual != crc {
        return Err(anyhow!(
            "segment {}@{}: payload crc {actual:08x} != recorded {crc:08x} — bit flip",
            path.display(),
            loc.offset
        ));
    }
    Ok(payload)
}

/// Delete files in the sidecar dir that `referenced` does not name
/// (superseded segments after compaction, temps from crashed writers).
/// Best-effort per file, loud on each removal; returns how many went.
pub fn remove_unreferenced(dir: &Path, referenced: &std::collections::HashSet<String>) -> usize {
    let mut removed = 0;
    for name in list_files(dir).unwrap_or_default() {
        if referenced.contains(&name) {
            continue;
        }
        match fs::remove_file(dir.join(&name)) {
            Ok(()) => {
                eprintln!("plan store: removed unreferenced segment file '{name}'");
                removed += 1;
            }
            Err(e) => eprintln!("plan store: could not remove '{name}': {e}"),
        }
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("anchor-segment-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn crc32_matches_the_ieee_check_value() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn write_then_read_round_trips_every_payload() {
        let dir = tmp_dir("roundtrip");
        let payloads: Vec<Vec<u8>> = vec![vec![1, 2, 3], vec![0xFF; 100], vec![7]];
        let refs: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
        let locs = write_segment(&dir, "seg-000001.bin", &refs).unwrap();
        assert_eq!(locs.len(), 3);
        assert_eq!(locs[0].offset, SEGMENT_HEADER_BYTES);
        for (loc, payload) in locs.iter().zip(&payloads) {
            assert_eq!(&read_payload(&dir, loc).unwrap(), payload);
        }
        // The file ends exactly at the last entry's end.
        let file_len = fs::metadata(dir.join("seg-000001.bin")).unwrap().len();
        assert_eq!(file_len, locs.last().unwrap().end());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn every_truncation_and_bit_flip_is_rejected() {
        let dir = tmp_dir("corrupt");
        let payloads: Vec<&[u8]> = vec![b"hello plan", b"goodbye plan"];
        let locs = write_segment(&dir, "seg-000001.bin", &payloads).unwrap();
        let path = dir.join("seg-000001.bin");
        let clean = fs::read(&path).unwrap();
        for cut in 0..clean.len() {
            fs::write(&path, &clean[..cut]).unwrap();
            let max_end = locs.iter().map(SegmentLoc::end).max().unwrap();
            assert!(
                check_segment(&dir, "seg-000001.bin", max_end).is_err(),
                "truncation at {cut} accepted"
            );
        }
        for i in 0..clean.len() {
            let mut bad = clean.clone();
            bad[i] ^= 0x41;
            fs::write(&path, &bad).unwrap();
            for loc in &locs {
                // The flipped byte either misses this entry (read fine and
                // bitwise-equal) or hits it (loud error) — never a silent
                // wrong payload.
                if let Ok(p) = read_payload(&dir, loc) {
                    let lo = (loc.offset + ENTRY_FRAME_BYTES) as usize;
                    let hi = loc.end() as usize;
                    assert!(
                        i < lo || i >= hi,
                        "flip at {i} inside payload [{lo},{hi}) read back cleanly"
                    );
                    assert_eq!(p, clean[lo..hi].to_vec());
                }
            }
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn naming_skips_temps_and_never_reuses_a_live_sequence() {
        let dir = tmp_dir("naming");
        fs::create_dir_all(&dir).unwrap();
        assert_eq!(next_segment_name(&dir).unwrap(), "seg-000001.bin");
        fs::write(dir.join("seg-000004.bin"), b"x").unwrap();
        fs::write(dir.join("seg-000002.bin.tmp.1.0"), b"x").unwrap();
        fs::write(dir.join("notes.txt"), b"x").unwrap();
        assert_eq!(next_segment_name(&dir).unwrap(), "seg-000005.bin");
        assert_eq!(segment_seq("seg-000004.bin"), Some(4));
        assert_eq!(segment_seq("seg-000002.bin.tmp.1.0"), None);
        assert_eq!(segment_seq("seg-x.bin"), None);
        let mut keep = std::collections::HashSet::new();
        keep.insert("seg-000004.bin".to_string());
        let removed = remove_unreferenced(&dir, &keep);
        assert_eq!(removed, 2);
        assert_eq!(list_files(&dir).unwrap(), vec!["seg-000004.bin".to_string()]);
        let _ = fs::remove_dir_all(&dir);
    }
}

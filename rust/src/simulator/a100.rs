//! A100-80GB roofline cost model.
//!
//! `time = max(flops / (eff · peak_flops), bytes / (eff_bw · hbm_bw)) +
//! fixed kernel overhead`. Constants follow the public A100 spec sheet and
//! the efficiency range measured for FlashAttention-2-class kernels
//! (~0.5–0.7 of peak on fp16/bf16 attention). Used only for Fig. 2 / 6
//! *latency-regime* translation — crossovers and ratios also come from the
//! measured CPU engine (DESIGN.md §6).

use crate::attention::CostTally;

#[derive(Clone, Copy, Debug)]
pub struct A100Model {
    /// Peak dense bf16/fp16 tensor-core throughput (FLOP/s).
    pub peak_flops: f64,
    /// HBM2e bandwidth (bytes/s).
    pub hbm_bw: f64,
    /// Achievable fraction of peak for attention matmuls.
    pub flop_eff: f64,
    /// Achievable fraction of HBM bandwidth for streaming loads.
    pub bw_eff: f64,
    /// Achievable fraction of HBM bandwidth for *gathered* (discrete) loads
    /// — the paper's kernel coalesces stripe gathers per §3.3, retaining
    /// most of the streaming rate.
    pub gather_eff: f64,
    /// Fixed launch/setup overhead per kernel phase (seconds).
    pub phase_overhead: f64,
}

impl Default for A100Model {
    fn default() -> Self {
        Self {
            peak_flops: 312e12,
            hbm_bw: 2.039e12,
            flop_eff: 0.55,
            bw_eff: 0.80,
            gather_eff: 0.60,
            phase_overhead: 12e-6,
        }
    }
}

/// Predicted phase time for a cost tally.
impl A100Model {
    /// Time for a contiguous-access phase (dense or block-sparse tiles).
    pub fn phase_time(&self, cost: &CostTally) -> f64 {
        self.time_inner(cost, self.bw_eff)
    }

    /// Time for a gather-access phase (discrete stripe loads).
    pub fn gather_phase_time(&self, cost: &CostTally) -> f64 {
        self.time_inner(cost, self.gather_eff)
    }

    fn time_inner(&self, cost: &CostTally, bw_eff: f64) -> f64 {
        if cost.flops == 0 && cost.kv_bytes == 0 {
            return 0.0;
        }
        let compute = cost.flops as f64 / (self.flop_eff * self.peak_flops);
        let memory = cost.kv_bytes as f64 / (bw_eff * self.hbm_bw);
        compute.max(memory) + self.phase_overhead
    }

    /// Dense causal attention time for one head (the Fig. 2 denominator).
    pub fn full_attention_time(&self, n: usize, d: usize) -> f64 {
        // Causal: ~n²/2 score entries; 4 flops each at head dim d.
        let entries = (n as u64 * n as u64) / 2;
        let cost = CostTally {
            flops: 4 * entries * d as u64,
            kv_bytes: 2 * (n * d * 2) as u64, // K+V streamed once, bf16
            ident_scores: 0,
        };
        self.phase_time(&cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn longer_context_takes_longer() {
        let m = A100Model::default();
        let t64 = m.full_attention_time(65536, 128);
        let t128 = m.full_attention_time(131072, 128);
        assert!(t128 > 3.0 * t64, "quadratic scaling: {t64} -> {t128}");
    }

    #[test]
    fn full_128k_in_plausible_range() {
        // One head, 128k, d=128: paper-scale kernels land in tens of ms.
        let m = A100Model::default();
        let t = m.full_attention_time(131072, 128);
        assert!(t > 5e-3 && t < 500e-3, "t = {t}s");
    }

    #[test]
    fn gather_slower_than_stream_when_memory_bound() {
        let m = A100Model::default();
        let cost = CostTally { flops: 1, kv_bytes: 1 << 30, ident_scores: 0 };
        assert!(m.gather_phase_time(&cost) > m.phase_time(&cost));
    }

    #[test]
    fn zero_cost_is_zero_time() {
        let m = A100Model::default();
        assert_eq!(m.phase_time(&CostTally::default()), 0.0);
    }

    #[test]
    fn compute_bound_vs_memory_bound() {
        let m = A100Model::default();
        // Heavy flops, no bytes -> compute-bound.
        let c = CostTally { flops: 1 << 50, kv_bytes: 0, ident_scores: 0 };
        let t = m.phase_time(&c);
        assert!((t - (c.flops as f64 / (m.flop_eff * m.peak_flops) + m.phase_overhead)).abs() < 1e-9);
    }
}

//! Analytic performance models (DESIGN.md §1, §5).
//!
//! The CPU engine measures *relative* latencies faithfully, but the paper
//! reports absolute A100 milliseconds; [`a100`] translates each method's
//! [`CostTally`](crate::attention::CostTally) into A100-regime time via a
//! roofline model. [`tpu`] estimates VMEM footprint and MXU utilization of
//! the Pallas kernels for the L1 perf targets (§Perf).

pub mod a100;
pub mod tpu;

//! TPU-side estimates for the L1 Pallas kernels (DESIGN.md §5).
//!
//! Pallas runs under `interpret=True` on the CPU PJRT plugin, so TPU
//! performance cannot be measured here; instead we model the kernels'
//! BlockSpec schedules: VMEM footprint per grid step (must fit the ~16 MiB
//! per-core budget, with double-buffering) and MXU utilization (fraction
//! of 128×128-systolic-array issue slots doing useful work). These numbers
//! gate the block-shape choices recorded in EXPERIMENTS.md §Perf.

/// TPU v4-like core parameters.
#[derive(Clone, Copy, Debug)]
pub struct TpuCore {
    /// VMEM bytes per core.
    pub vmem_bytes: usize,
    /// MXU systolic dimension (128 for v4/v5).
    pub mxu_dim: usize,
    /// Peak bf16 MACs per cycle (one 128×128 MXU issue).
    pub macs_per_cycle: usize,
}

impl Default for TpuCore {
    fn default() -> Self {
        Self { vmem_bytes: 16 << 20, mxu_dim: 128, macs_per_cycle: 128 * 128 }
    }
}

/// One kernel's tile schedule (what BlockSpec pins in VMEM per grid step).
#[derive(Clone, Copy, Debug)]
pub struct KernelTiles {
    pub b_q: usize,
    pub b_kv: usize,
    pub d: usize,
    /// Bytes per element (2 = bf16, 4 = f32).
    pub elem_bytes: usize,
    /// Buffers resident per step: Q tile, K tile, V tile, acc, m/l.
    pub double_buffered: bool,
}

#[derive(Clone, Copy, Debug)]
pub struct TileEstimate {
    pub vmem_bytes: usize,
    pub vmem_frac: f64,
    /// Utilization of MXU issue slots for the QKᵀ matmul of one tile.
    pub mxu_utilization: f64,
    pub fits: bool,
}

/// Estimate VMEM footprint + MXU utilization for a tile schedule.
pub fn estimate(core: &TpuCore, t: &KernelTiles) -> TileEstimate {
    let eb = t.elem_bytes;
    // Resident per grid step: Q [b_q, d], K [b_kv, d], V [b_kv, d],
    // acc [b_q, d] (f32), m+l [b_q] (f32), scores [b_q, b_kv] (f32).
    let stream = (t.b_kv * t.d) * eb * 2; // K + V tiles stream per step
    let fixed = (t.b_q * t.d) * eb            // Q tile
        + (t.b_q * t.d) * 4                   // acc (f32)
        + 2 * t.b_q * 4                       // m, l
        + (t.b_q * t.b_kv) * 4; // scores scratch
    let mult = if t.double_buffered { 2 } else { 1 };
    let vmem = fixed + stream * mult;

    // MXU utilization: a [b_q, d] × [d, b_kv] matmul issues
    // ceil(b_q/128)·ceil(d/128)·ceil(b_kv/128) passes of the 128×128 array;
    // utilization = useful MACs / (passes · 128·128·128-cycle volume).
    let m128 = |x: usize| x.div_ceil(core.mxu_dim);
    let passes = m128(t.b_q) * m128(t.d) * m128(t.b_kv);
    let ideal = t.b_q * t.d * t.b_kv;
    let issued = passes * core.mxu_dim * core.mxu_dim * core.mxu_dim;
    let mxu_utilization = ideal as f64 / issued as f64;

    TileEstimate {
        vmem_bytes: vmem,
        vmem_frac: vmem as f64 / core.vmem_bytes as f64,
        mxu_utilization,
        fits: vmem <= core.vmem_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_tile_fits_vmem() {
        // The paper's (128, 128) tiles at d=128, bf16, double-buffered.
        let e = estimate(
            &TpuCore::default(),
            &KernelTiles { b_q: 128, b_kv: 128, d: 128, elem_bytes: 2, double_buffered: true },
        );
        assert!(e.fits, "vmem {} bytes", e.vmem_bytes);
        assert!(e.vmem_frac < 0.1);
        assert!((e.mxu_utilization - 1.0).abs() < 1e-9, "aligned tiles use full MXU");
    }

    #[test]
    fn misaligned_tiles_waste_mxu() {
        let e = estimate(
            &TpuCore::default(),
            &KernelTiles { b_q: 64, b_kv: 64, d: 64, elem_bytes: 2, double_buffered: false },
        );
        // 64³ useful / 128³ issued = 1/8.
        assert!((e.mxu_utilization - 0.125).abs() < 1e-9);
    }

    #[test]
    fn oversized_tiles_overflow() {
        let e = estimate(
            &TpuCore::default(),
            &KernelTiles { b_q: 4096, b_kv: 4096, d: 128, elem_bytes: 4, double_buffered: true },
        );
        assert!(!e.fits);
    }

    #[test]
    fn double_buffering_costs_stream_only() {
        let base = KernelTiles { b_q: 128, b_kv: 128, d: 128, elem_bytes: 2, double_buffered: false };
        let single = estimate(&TpuCore::default(), &base);
        let double = estimate(
            &TpuCore::default(),
            &KernelTiles { double_buffered: true, ..base },
        );
        let stream = 2 * 128 * 128 * 2;
        assert_eq!(double.vmem_bytes - single.vmem_bytes, stream);
    }
}

//! Minimal dense f32 tensor substrate.
//!
//! The attention engine works per head with row-major matrices
//! (`[rows, cols]`), so a 2-D [`Mat`] plus a handful of blocked kernels is
//! all the linear algebra this project needs. The two matmul flavors are
//! shaped for attention:
//!
//! * [`matmul_nt`] — `C = A · Bᵀ` where both operands are `[*, d]` row-major;
//!   this is exactly `Q · Kᵀ` (rows of K are contiguous, so the inner loop is
//!   a dot product of contiguous slices — cache-friendly, vectorizable).
//! * [`matmul_nn`] — `C = A · B`, i.e. `P · V`.
//!
//! Kernels are written as straight safe Rust with accumulator unrolling;
//! the perf pass (EXPERIMENTS.md §Perf) iterates on the micro-kernels.

pub mod ops;

/// Row-major 2-D f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// View of rows `[start, start+len)` as a borrowed sub-matrix slice.
    pub fn rows_slice(&self, start: usize, len: usize) -> &[f32] {
        debug_assert!(start + len <= self.rows);
        &self.data[start * self.cols..(start + len) * self.cols]
    }

    /// Copy of rows `[start, start+len)` as a new Mat.
    pub fn rows_mat(&self, start: usize, len: usize) -> Mat {
        Mat::from_vec(len, self.cols, self.rows_slice(start, len).to_vec())
    }

    /// Gather the given rows into a new, contiguous matrix (the engine's
    /// `load_discrete` primitive — Eq. 4 of the paper).
    pub fn gather_rows(&self, idx: &[u32]) -> Mat {
        let mut out = Vec::with_capacity(idx.len() * self.cols);
        for &i in idx {
            out.extend_from_slice(self.row(i as usize));
        }
        Mat::from_vec(idx.len(), self.cols, out)
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Frobenius-norm relative error vs `other` — the output-fidelity
    /// metric used throughout the experiment harness.
    pub fn rel_err(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            let d = (*a - *b) as f64;
            num += d * d;
            den += (*b as f64) * (*b as f64);
        }
        if den == 0.0 {
            num.sqrt()
        } else {
            (num / den).sqrt()
        }
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// `C = A · Bᵀ` with `A: [m, k]`, `B: [n, k]`, `C: [m, n]`.
/// Row-dot formulation: both inner operands are contiguous rows.
pub fn matmul_nt(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.cols, "inner dims");
    assert_eq!((c.rows, c.cols), (a.rows, b.rows), "output shape");
    let k = a.cols;
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..b.rows {
            crow[j] = dot(arow, b.row(j), k);
        }
    }
}

/// Scaled variant: `C = (A · Bᵀ) * scale` — fuses the 1/√d of attention.
/// Dispatches to a const-width kernel for the attention head dims the
/// engine actually runs (d ∈ {64, 128}) so the inner loops unroll with
/// compile-time trip counts and auto-vectorize; the generic path is the
/// fallback and is bitwise-equal (identical accumulator order, only the
/// loop bound becomes a constant).
pub fn matmul_nt_scaled(a: &Mat, b: &Mat, scale: f32, c: &mut Mat) {
    match a.cols {
        64 => matmul_nt_scaled_k::<64>(a, b, scale, c),
        128 => matmul_nt_scaled_k::<128>(a, b, scale, c),
        _ => matmul_nt_scaled_generic(a, b, scale, c),
    }
}

/// Generic-width `C = (A · Bᵀ) * scale` — the reference the specialized
/// kernels are tested bitwise-equal against.
pub fn matmul_nt_scaled_generic(a: &Mat, b: &Mat, scale: f32, c: &mut Mat) {
    assert_eq!(a.cols, b.cols, "inner dims");
    assert_eq!((c.rows, c.cols), (a.rows, b.rows), "output shape");
    let k = a.cols;
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        // Process 4 B-rows at a time to amortize A-row loads.
        let mut j = 0;
        while j + 4 <= b.rows {
            let (d0, d1, d2, d3) = dot4(arow, b.row(j), b.row(j + 1), b.row(j + 2), b.row(j + 3), k);
            crow[j] = d0 * scale;
            crow[j + 1] = d1 * scale;
            crow[j + 2] = d2 * scale;
            crow[j + 3] = d3 * scale;
            j += 4;
        }
        while j < b.rows {
            crow[j] = dot(arow, b.row(j), k) * scale;
            j += 1;
        }
    }
}

/// Const-width `C = (A · Bᵀ) * scale`: same walk as the generic kernel
/// with the inner dim pinned to `K`, so `dot`/`dot4` see constant trip
/// counts (and, with K % 4 == 0, empty tails).
fn matmul_nt_scaled_k<const K: usize>(a: &Mat, b: &Mat, scale: f32, c: &mut Mat) {
    assert_eq!(a.cols, K, "inner dims");
    assert_eq!(b.cols, K, "inner dims");
    assert_eq!((c.rows, c.cols), (a.rows, b.rows), "output shape");
    for i in 0..a.rows {
        let arow = &a.row(i)[..K];
        let crow = c.row_mut(i);
        let mut j = 0;
        while j + 4 <= b.rows {
            let (d0, d1, d2, d3) =
                dot4_k::<K>(arow, b.row(j), b.row(j + 1), b.row(j + 2), b.row(j + 3));
            crow[j] = d0 * scale;
            crow[j + 1] = d1 * scale;
            crow[j + 2] = d2 * scale;
            crow[j + 3] = d3 * scale;
            j += 4;
        }
        while j < b.rows {
            crow[j] = dot_k::<K>(arow, b.row(j)) * scale;
            j += 1;
        }
    }
}

/// `C += A · B` with `A: [m, k]`, `B: [k, n]`, `C: [m, n]`. Dispatches on
/// the row width `n` (the attention head dim in the `P · V` accumulate)
/// to a const-width kernel for d ∈ {64, 128}; generic fallback is
/// bitwise-equal.
pub fn matmul_nn_acc(a: &Mat, b: &Mat, c: &mut Mat) {
    match b.cols {
        64 => matmul_nn_acc_k::<64>(a, b, c),
        128 => matmul_nn_acc_k::<128>(a, b, c),
        _ => matmul_nn_acc_generic(a, b, c),
    }
}

/// Generic-width `C += A · B` — the reference the specialized kernels are
/// tested bitwise-equal against.
pub fn matmul_nn_acc_generic(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows, "inner dims");
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "output shape");
    let n = b.cols;
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = &mut c.data[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // sparse P rows skip work
            }
            let brow = &b.data[kk * n..(kk + 1) * n];
            axpy(av, brow, crow);
        }
    }
}

/// Const-width `C += A · B`: the `axpy` rows are pinned to `N` elements,
/// so with N % 8 == 0 the 8-wide unroll has no tail and a constant count.
fn matmul_nn_acc_k<const N: usize>(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows, "inner dims");
    assert_eq!(b.cols, N, "row width");
    assert_eq!((c.rows, c.cols), (a.rows, N), "output shape");
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = &mut c.data[i * N..(i + 1) * N];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // sparse P rows skip work
            }
            let brow = &b.data[kk * N..(kk + 1) * N];
            axpy_k::<N>(av, brow, crow);
        }
    }
}

/// `y += a * x` over slices.
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // 8-wide unroll: LLVM auto-vectorizes this cleanly.
    let n = x.len();
    let chunks = n / 8;
    for c in 0..chunks {
        let i = c * 8;
        y[i] += a * x[i];
        y[i + 1] += a * x[i + 1];
        y[i + 2] += a * x[i + 2];
        y[i + 3] += a * x[i + 3];
        y[i + 4] += a * x[i + 4];
        y[i + 5] += a * x[i + 5];
        y[i + 6] += a * x[i + 6];
        y[i + 7] += a * x[i + 7];
    }
    for i in chunks * 8..n {
        y[i] += a * x[i];
    }
}

/// Dot product of two contiguous slices with 4 accumulators.
#[inline]
pub fn dot(a: &[f32], b: &[f32], k: usize) -> f32 {
    debug_assert!(a.len() >= k && b.len() >= k);
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    let chunks = k / 4;
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..k {
        s += a[i] * b[i];
    }
    s
}

/// Four simultaneous dot products sharing one A-row load.
#[inline]
#[allow(clippy::too_many_arguments)]
fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32], k: usize) -> (f32, f32, f32, f32) {
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    for i in 0..k {
        let av = a[i];
        s0 += av * b0[i];
        s1 += av * b1[i];
        s2 += av * b2[i];
        s3 += av * b3[i];
    }
    (s0, s1, s2, s3)
}

// Const-width forms of the three primitives above. Each body is the same
// accumulator pattern with the loop bound a compile-time constant and the
// operand slices pinned to `[..K]`, which is what lets LLVM drop the
// bounds checks and emit full-width vector code — the arithmetic (values,
// order, associativity) is unchanged, so results are bitwise-equal to the
// generic forms.

/// `dot` with a const trip count (K % 4 == 0 ⇒ no scalar tail).
#[inline]
fn dot_k<const K: usize>(a: &[f32], b: &[f32]) -> f32 {
    let a = &a[..K];
    let b = &b[..K];
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    let chunks = K / 4;
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..K {
        s += a[i] * b[i];
    }
    s
}

/// `dot4` with a const trip count.
#[inline]
fn dot4_k<const K: usize>(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> (f32, f32, f32, f32) {
    let a = &a[..K];
    let b0 = &b0[..K];
    let b1 = &b1[..K];
    let b2 = &b2[..K];
    let b3 = &b3[..K];
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    for i in 0..K {
        let av = a[i];
        s0 += av * b0[i];
        s1 += av * b1[i];
        s2 += av * b2[i];
        s3 += av * b3[i];
    }
    (s0, s1, s2, s3)
}

/// `axpy` with a const element count (N % 8 == 0 ⇒ no tail).
#[inline]
fn axpy_k<const N: usize>(a: f32, x: &[f32], y: &mut [f32]) {
    let x = &x[..N];
    let y = &mut y[..N];
    let chunks = N / 8;
    for c in 0..chunks {
        let i = c * 8;
        y[i] += a * x[i];
        y[i + 1] += a * x[i + 1];
        y[i + 2] += a * x[i + 2];
        y[i + 3] += a * x[i + 3];
        y[i + 4] += a * x[i + 4];
        y[i + 5] += a * x[i + 5];
        y[i + 6] += a * x[i + 6];
        y[i + 7] += a * x[i + 7];
    }
    for i in chunks * 8..N {
        y[i] += a * x[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    fn matmul_naive_nt(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.rows);
        for i in 0..a.rows {
            for j in 0..b.rows {
                let mut s = 0.0;
                for kk in 0..a.cols {
                    s += a.at(i, kk) * b.at(j, kk);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_nt_matches_naive() {
        let mut rng = Pcg64::seeded(11);
        for (m, n, k) in [(1, 1, 1), (3, 5, 7), (16, 16, 64), (33, 17, 63)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, n, k);
            let mut c = Mat::zeros(m, n);
            matmul_nt(&a, &b, &mut c);
            let expect = matmul_naive_nt(&a, &b);
            assert!(c.max_abs_diff(&expect) < 1e-4, "m={m} n={n} k={k}");
        }
    }

    #[test]
    fn matmul_nt_scaled_matches() {
        let mut rng = Pcg64::seeded(12);
        let a = rand_mat(&mut rng, 9, 32);
        let b = rand_mat(&mut rng, 13, 32);
        let mut c1 = Mat::zeros(9, 13);
        let mut c2 = Mat::zeros(9, 13);
        matmul_nt(&a, &b, &mut c1);
        matmul_nt_scaled(&a, &b, 0.25, &mut c2);
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert!((x * 0.25 - y).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_nn_acc_matches_naive() {
        let mut rng = Pcg64::seeded(13);
        let a = rand_mat(&mut rng, 7, 11);
        let b = rand_mat(&mut rng, 11, 5);
        let mut c = Mat::zeros(7, 5);
        matmul_nn_acc(&a, &b, &mut c);
        for i in 0..7 {
            for j in 0..5 {
                let mut s = 0.0;
                for kk in 0..11 {
                    s += a.at(i, kk) * b.at(kk, j);
                }
                assert!((c.at(i, j) - s).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn matmul_nn_accumulates() {
        let a = Mat::from_vec(1, 1, vec![2.0]);
        let b = Mat::from_vec(1, 1, vec![3.0]);
        let mut c = Mat::from_vec(1, 1, vec![10.0]);
        matmul_nn_acc(&a, &b, &mut c);
        assert_eq!(c.at(0, 0), 16.0);
    }

    /// The d-specialized kernels must be bitwise-equal to the generic
    /// walk — same accumulator order, only constant trip counts.
    #[test]
    fn specialized_matmuls_bitwise_equal_generic_at_64_and_128() {
        let mut rng = Pcg64::seeded(21);
        for d in [64usize, 128] {
            // Ragged row counts exercise the dot4 remainder path.
            for (m, n) in [(1, 1), (5, 7), (16, 16), (13, 19)] {
                let a = rand_mat(&mut rng, m, d);
                let b = rand_mat(&mut rng, n, d);
                let mut c_spec = Mat::zeros(m, n);
                let mut c_gen = Mat::zeros(m, n);
                matmul_nt_scaled(&a, &b, 0.125, &mut c_spec);
                matmul_nt_scaled_generic(&a, &b, 0.125, &mut c_gen);
                assert_eq!(c_spec.data, c_gen.data, "nt d={d} m={m} n={n}");

                // P · V accumulate with some exact zeros (the sparse skip).
                let mut p = rand_mat(&mut rng, m, n);
                for (i, x) in p.data.iter_mut().enumerate() {
                    if i % 3 == 0 {
                        *x = 0.0;
                    }
                }
                let v = rand_mat(&mut rng, n, d);
                let mut acc_spec = rand_mat(&mut rng, m, d);
                let mut acc_gen = acc_spec.clone();
                matmul_nn_acc(&p, &v, &mut acc_spec);
                matmul_nn_acc_generic(&p, &v, &mut acc_gen);
                assert_eq!(acc_spec.data, acc_gen.data, "nn d={d} m={m} n={n}");
            }
        }
    }

    #[test]
    fn gather_rows_matches_manual() {
        let m = Mat::from_fn(6, 3, |r, c| (r * 10 + c) as f32);
        let g = m.gather_rows(&[4, 0, 2]);
        assert_eq!(g.rows, 3);
        assert_eq!(g.row(0), &[40.0, 41.0, 42.0]);
        assert_eq!(g.row(1), &[0.0, 1.0, 2.0]);
        assert_eq!(g.row(2), &[20.0, 21.0, 22.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(14);
        let m = rand_mat(&mut rng, 5, 8);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn rel_err_zero_for_identical() {
        let mut rng = Pcg64::seeded(15);
        let m = rand_mat(&mut rng, 4, 4);
        assert_eq!(m.rel_err(&m), 0.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0]);
    }
}

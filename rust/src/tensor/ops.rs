//! Elementwise / reduction ops used by the attention engine.

use super::Mat;

/// In-place numerically-stable softmax over each row.
pub fn softmax_rows(m: &mut Mat) {
    for r in 0..m.rows {
        let row = m.row_mut(r);
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        if !mx.is_finite() {
            // All -inf (fully masked row): define softmax as zeros.
            row.iter_mut().for_each(|x| *x = 0.0);
            continue;
        }
        let mut sum = 0.0f32;
        for x in row.iter_mut() {
            *x = (*x - mx).exp();
            sum += *x;
        }
        let inv = 1.0 / sum;
        row.iter_mut().for_each(|x| *x *= inv);
    }
}

/// Average-pool rows in groups of `block`: output has `ceil(rows/block)`
/// rows. This is the paper's `avgpool(Q, b_q)` (Alg. 2 line 1).
pub fn avgpool_rows(m: &Mat, block: usize) -> Mat {
    assert!(block >= 1);
    let out_rows = m.rows.div_ceil(block);
    let mut out = Mat::zeros(out_rows, m.cols);
    for g in 0..out_rows {
        let start = g * block;
        let end = (start + block).min(m.rows);
        let inv = 1.0 / (end - start) as f32;
        let orow = out.row_mut(g);
        for r in start..end {
            let irow = &m.data[r * m.cols..(r + 1) * m.cols];
            for (o, &x) in orow.iter_mut().zip(irow) {
                *o += x;
            }
        }
        orow.iter_mut().for_each(|x| *x *= inv);
    }
    out
}

/// Average-pool a vector in groups of `block` (used for `avgpool(x_a)`).
pub fn avgpool_vec(v: &[f32], block: usize) -> Vec<f32> {
    assert!(block >= 1);
    let out_len = v.len().div_ceil(block);
    let mut out = Vec::with_capacity(out_len);
    for g in 0..out_len {
        let start = g * block;
        let end = (start + block).min(v.len());
        let s: f32 = v[start..end].iter().sum();
        out.push(s / (end - start) as f32);
    }
    out
}

/// Row-wise maximum.
pub fn rowmax(m: &Mat) -> Vec<f32> {
    (0..m.rows)
        .map(|r| m.row(r).iter().copied().fold(f32::NEG_INFINITY, f32::max))
        .collect()
}

/// Apply a causal mask in logit space: positions `j > row_offset + r` get
/// `-inf`. `row_offset` is the absolute position of row 0.
pub fn causal_mask_inplace(m: &mut Mat, row_offset: usize, col_offset: usize) {
    for r in 0..m.rows {
        let limit = row_offset + r; // keys with absolute pos <= limit are visible
        let row = m.row_mut(r);
        for (c, x) in row.iter_mut().enumerate() {
            if col_offset + c > limit {
                *x = f32::NEG_INFINITY;
            }
        }
    }
}

/// RMS norm of a vector (for the rust-side model mirror).
pub fn rmsnorm(x: &[f32], weight: &[f32], eps: f32, out: &mut [f32]) {
    assert_eq!(x.len(), weight.len());
    assert_eq!(x.len(), out.len());
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((o, &v), &w) in out.iter_mut().zip(x).zip(weight) {
        *o = v * inv * w;
    }
}

/// SiLU activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_normalizes() {
        let mut m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        softmax_rows(&mut m);
        for r in 0..2 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            // Monotone in the logits.
            assert!(m.at(r, 0) < m.at(r, 1) && m.at(r, 1) < m.at(r, 2));
        }
    }

    #[test]
    fn softmax_handles_fully_masked_row() {
        let mut m = Mat::from_vec(1, 2, vec![f32::NEG_INFINITY, f32::NEG_INFINITY]);
        softmax_rows(&mut m);
        assert_eq!(m.row(0), &[0.0, 0.0]);
    }

    #[test]
    fn softmax_invariant_to_shift() {
        let mut a = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let mut b = Mat::from_vec(1, 3, vec![1001.0, 1002.0, 1003.0]);
        softmax_rows(&mut a);
        softmax_rows(&mut b);
        assert!(a.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn avgpool_rows_exact_blocks() {
        let m = Mat::from_vec(4, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let p = avgpool_rows(&m, 2);
        assert_eq!(p.rows, 2);
        assert_eq!(p.row(0), &[2.0, 3.0]);
        assert_eq!(p.row(1), &[6.0, 7.0]);
    }

    #[test]
    fn avgpool_rows_ragged_tail() {
        let m = Mat::from_vec(3, 1, vec![1.0, 2.0, 10.0]);
        let p = avgpool_rows(&m, 2);
        assert_eq!(p.rows, 2);
        assert_eq!(p.at(0, 0), 1.5);
        assert_eq!(p.at(1, 0), 10.0);
    }

    #[test]
    fn avgpool_vec_basic() {
        assert_eq!(avgpool_vec(&[2.0, 4.0, 6.0], 2), vec![3.0, 6.0]);
        assert_eq!(avgpool_vec(&[5.0], 4), vec![5.0]);
    }

    #[test]
    fn causal_mask_zeroes_future() {
        let mut m = Mat::from_vec(2, 4, vec![1.0; 8]);
        causal_mask_inplace(&mut m, 1, 0); // rows are absolute positions 1,2
        assert_eq!(m.row(0), &[1.0, 1.0, f32::NEG_INFINITY, f32::NEG_INFINITY]);
        assert_eq!(m.row(1), &[1.0, 1.0, 1.0, f32::NEG_INFINITY]);
    }

    #[test]
    fn rowmax_masks() {
        let m = Mat::from_vec(2, 2, vec![3.0, 1.0, -5.0, -2.0]);
        assert_eq!(rowmax(&m), vec![3.0, -2.0]);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = [3.0f32, 4.0];
        let w = [1.0f32, 1.0];
        let mut out = [0.0f32; 2];
        rmsnorm(&x, &w, 0.0, &mut out);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-6);
        assert!((out[1] - 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn silu_known_values() {
        assert!((silu(0.0) - 0.0).abs() < 1e-7);
        assert!(silu(10.0) > 9.99);
        assert!(silu(-10.0).abs() < 1e-3);
    }
}

//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Typed getters parse on access and report readable errors.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args().skip(1)`.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> anyhow::Result<f32> {
        Ok(self.f64_or(key, default as f64)? as f32)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(anyhow::anyhow!("--{key} expects a bool, got '{v}'")),
        }
    }

    /// Comma-separated list of usizes, e.g. `--lengths 4096,8192`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{key}: bad integer '{p}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(parts: &[&str]) -> Args {
        Args::parse(parts.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        // NOTE: a bare `--flag` consumes the next token unless it starts with
        // `--`, so boolean flags must come last or use `--flag=true`.
        let a = parse(&["serve", "--port", "8080", "--theta=12.5", "trace.json", "--verbose"]);
        assert_eq!(a.subcommand(), Some("serve"));
        assert_eq!(a.usize_or("port", 0).unwrap(), 8080);
        assert_eq!(a.f64_or("theta", 0.0).unwrap(), 12.5);
        assert!(a.bool_or("verbose", false).unwrap());
        assert_eq!(a.positional(), &["serve".to_string(), "trace.json".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("n", 42).unwrap(), 42);
        assert_eq!(a.str_or("mode", "full"), "full");
        assert!(!a.bool_or("x", false).unwrap());
        assert_eq!(a.subcommand(), None);
    }

    #[test]
    fn bad_values_error() {
        let a = parse(&["--n", "abc"]);
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--lengths", "4096, 8192,16384"]);
        assert_eq!(a.usize_list_or("lengths", &[]).unwrap(), vec![4096, 8192, 16384]);
        let b = parse(&[]);
        assert_eq!(b.usize_list_or("lengths", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["--fast"]);
        assert!(a.bool_or("fast", false).unwrap());
    }
}

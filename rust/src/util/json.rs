//! Minimal JSON parser and serializer.
//!
//! `serde`/`serde_json` are not available in the offline build environment,
//! so configs (`configs/*.json`), the artifact manifest written by
//! `python/compile/aot.py`, and experiment reports go through this module.
//! It supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) with precise error positions.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept in sorted order (BTreeMap) so
/// serialization is deterministic — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing data after document"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|x| if x.fract() == 0.0 { Some(x as i64) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns `Json::Null` for missing keys so lookups
    /// chain without panicking.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index access, `Json::Null` out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            cp
                        };
                        s.push(char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        self.pos = start + len;
                        if self.pos > self.src.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.src[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "1e3"] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn nested_structure() {
        let src = r#"{"a": [1, 2, {"b": "x", "c": null}], "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("x"));
        assert!(v.get("a").idx(2).get("c").is_null());
        assert_eq!(v.get("d").as_bool(), Some(true));
        assert_eq!(v.get("a").idx(0).as_usize(), Some(1));
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" é 😀"));
        // Round-trip.
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse("{}").unwrap();
        assert!(v.get("nope").is_null());
        assert!(v.get("nope").get("deeper").is_null());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("x", Json::num(1.0)),
            ("y", Json::arr([Json::str("a"), Json::Null])),
        ]);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_serialized_without_fraction() {
        assert_eq!(Json::num(5.0).to_string(), "5");
        assert_eq!(Json::num(5.25).to_string(), "5.25");
    }
}

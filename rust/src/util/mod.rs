//! Infrastructure substrates for the offline build environment.
//!
//! The hermetic build sandbox only ships the `xla` crate's dependency
//! closure, so the usual ecosystem crates (`rand`, `serde_json`, `clap`,
//! `criterion`, `rayon`, `proptest`) are re-implemented here at the scale
//! this project needs. Each submodule is self-contained and unit-tested.

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timer;

/// Write a CSV report under `reports/`, creating the directory if needed.
/// Returns the written path.
pub fn write_report(name: &str, contents: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("reports");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, contents)?;
    Ok(path)
}

/// Format a fraction as a percentage with one decimal, e.g. `0.937 -> "93.7%"`.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Human-readable sequence length, e.g. 131072 -> "128k".
pub fn fmt_len(n: usize) -> String {
    if n % 1024 == 0 {
        format!("{}k", n / 1024)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.937), "93.7%");
        assert_eq!(pct(1.0), "100.0%");
    }

    #[test]
    fn fmt_len_powers() {
        assert_eq!(fmt_len(131072), "128k");
        assert_eq!(fmt_len(4096), "4k");
        assert_eq!(fmt_len(1000), "1000");
    }
}

//! Miniature property-based testing harness (proptest is unavailable
//! offline). Generates random cases from a seeded [`Pcg64`], checks a
//! property, and on failure greedily shrinks via a user-supplied shrinker
//! before reporting the minimal counterexample.
//!
//! Used by the coordinator invariants (routing, batching, KV-cache state)
//! and the attention-engine metamorphic tests.

use crate::util::rng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 64, seed: 0xC0FFEE, max_shrink_steps: 200 }
    }
}

/// Outcome of a single property check.
pub type CheckResult = Result<(), String>;

/// Run `prop` over `cfg.cases` random inputs produced by `gen`. On failure,
/// repeatedly apply `shrink` (which proposes smaller candidates) while the
/// property still fails, then panic with the minimal failing case.
pub fn check<T, G, S, P>(cfg: &Config, mut gen: G, shrink: S, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> CheckResult,
{
    let mut rng = Pcg64::seeded(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Shrink loop: greedy descent over candidates.
            let mut best = input.clone();
            let mut best_msg = first_msg;
            let mut steps = 0;
            'outer: while steps < cfg.max_shrink_steps {
                for cand in shrink(&best) {
                    steps += 1;
                    if let Err(msg) = prop(&cand) {
                        best = cand;
                        best_msg = msg;
                        continue 'outer;
                    }
                    if steps >= cfg.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}/{}, seed {:#x}):\n  input: {:?}\n  error: {}",
                cfg.cases, cfg.seed, best, best_msg
            );
        }
    }
}

/// Convenience: assert-style helper for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> CheckResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Standard shrinker for a vector: drop halves, drop single elements,
/// and shrink individual elements with `elem_shrink`.
pub fn shrink_vec<T: Clone>(xs: &[T], elem_shrink: impl Fn(&T) -> Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = xs.len();
    if n == 0 {
        return out;
    }
    // Halves.
    out.push(xs[..n / 2].to_vec());
    out.push(xs[n / 2..].to_vec());
    // Remove one element (first few positions only, to bound candidates).
    for i in 0..n.min(8) {
        let mut v = xs.to_vec();
        v.remove(i);
        out.push(v);
    }
    // Shrink one element.
    for i in 0..n.min(8) {
        for cand in elem_shrink(&xs[i]) {
            let mut v = xs.to_vec();
            v[i] = cand;
            out.push(v);
        }
    }
    out
}

/// Standard shrinker for usize: towards zero by halving.
pub fn shrink_usize(x: &usize) -> Vec<usize> {
    let x = *x;
    if x == 0 {
        vec![]
    } else {
        vec![0, x / 2, x - 1].into_iter().filter(|&y| y != x).collect()
    }
}

/// Shrinker for u64 seeds and sizes: towards zero by halving.
pub fn shrink_u64(x: &u64) -> Vec<u64> {
    let x = *x;
    if x == 0 {
        vec![]
    } else {
        vec![0, x / 2, x - 1].into_iter().filter(|&y| y != x).collect()
    }
}

/// Pick one element of a non-empty slice uniformly (generator helper).
pub fn choose<'a, T>(rng: &mut Pcg64, xs: &'a [T]) -> &'a T {
    assert!(!xs.is_empty());
    &xs[rng.next_below(xs.len() as u64) as usize]
}

impl Config {
    /// A reduced-case configuration for expensive properties (attention
    /// parity sweeps), keeping tier-1 wallclock bounded.
    pub fn heavy(cases: usize, seed: u64) -> Self {
        Self { cases, seed, max_shrink_steps: 40 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let cfg = Config { cases: 32, ..Default::default() };
        check(
            &cfg,
            |rng| rng.next_below(1000) as usize,
            |x| shrink_usize(x),
            |&x| ensure(x < 1000, "in range"),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_input() {
        let cfg = Config { cases: 64, ..Default::default() };
        check(
            &cfg,
            |rng| rng.next_below(10_000) as usize,
            |x| shrink_usize(x),
            // Fails for x >= 50; the shrinker should home in near 50.
            |&x| ensure(x < 50, format!("x={x} >= 50")),
        );
    }

    #[test]
    fn shrink_vec_produces_smaller_candidates() {
        let xs = vec![5usize, 6, 7, 8];
        let cands = shrink_vec(&xs, shrink_usize);
        assert!(!cands.is_empty());
        assert!(cands.iter().any(|c| c.len() < xs.len()));
    }

    #[test]
    fn shrink_u64_and_choose_helpers() {
        assert_eq!(shrink_u64(&0), Vec::<u64>::new());
        let c = shrink_u64(&10);
        assert!(c.contains(&0) && c.contains(&5) && c.contains(&9));
        let mut rng = Pcg64::seeded(1);
        let xs = [3, 5, 7];
        for _ in 0..20 {
            assert!(xs.contains(choose(&mut rng, &xs)));
        }
        let cfg = Config::heavy(4, 9);
        assert_eq!((cfg.cases, cfg.seed), (4, 9));
    }

    #[test]
    fn deterministic_across_runs() {
        // Same seed -> same generated sequence -> same (non-)failure.
        let cfg = Config { cases: 16, seed: 42, ..Default::default() };
        let mut seen1 = Vec::new();
        check(
            &cfg,
            |rng| {
                let v = rng.next_u64();
                seen1.push(v);
                v
            },
            |_| vec![],
            |_| Ok(()),
        );
        let mut seen2 = Vec::new();
        check(
            &cfg,
            |rng| {
                let v = rng.next_u64();
                seen2.push(v);
                v
            },
            |_| vec![],
            |_| Ok(()),
        );
        assert_eq!(seen1, seen2);
    }
}

//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so we carry our own
//! small, well-tested generators: [`SplitMix64`] for seeding and cheap
//! streams, and [`Pcg64`] as the workhorse generator used by the workload
//! synthesizer. Both are deterministic across platforms, which matters for
//! reproducible experiment tables.

/// SplitMix64 — tiny, fast, passes BigCrush when used for seeding.
/// Reference: Steele, Lea, Flood, "Fast Splittable Pseudorandom Number
/// Generators" (OOPSLA 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32 with 128-bit state emulated via two 64-bit lanes
/// (classic `pcg64` variant). Good statistical quality for simulation use.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Seed the generator. Two independent streams with the same `seed`
    /// but different `stream` ids never overlap.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ 0xA076_1D64_78BD_642F);
        let s0 = (sm.next_u64() as u128) << 64 | sm.next_u64() as u128;
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(s0);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Single-argument convenience seeding (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        // XSL-RR output function.
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 high-quality bits -> mantissa.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Lemire's unbiased bounded sampling.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; throughput is not a bottleneck for workload generation).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Used by the serving
    /// trace generator for Poisson arrivals.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm: O(k) expected memory, no O(n) scratch.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_below(j as u64 + 1) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        self.shuffle(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg64::new(7, 0);
        let mut b = Pcg64::new(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "independent streams should not collide");
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg64::seeded(1);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bounded_unbiased_small_range() {
        let mut r = Pcg64::seeded(2);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[r.next_below(5) as usize] += 1;
        }
        for &c in &counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.2).abs() < 0.02, "p={p}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::seeded(4);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.exponential(2.0)).sum();
        assert!((s / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Pcg64::seeded(5);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(6);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

//! Small statistics helpers shared by metrics, benches and the scheduler.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile with linear interpolation, `q` in [0, 100].
///
/// Non-finite samples (NaN, ±inf) are skipped: shed or failed requests
/// carry NaN latencies, and a `pub` helper must not panic in the sort
/// (or interpolate against an infinity) because one caller forgot to
/// pre-filter. Returns 0.0 when no finite sample remains — callers that
/// gate on the result must treat that as "no data", not "fast"
/// (see `serve_bench::check_slo`).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut sorted: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if sorted.is_empty() {
        return 0.0;
    }
    sorted.sort_by(f64::total_cmp);
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Simple streaming histogram with fixed buckets for latency tracking in the
/// serving metrics pipeline. Buckets are [edges[i], edges[i+1]).
#[derive(Debug, Clone)]
pub struct Histogram {
    edges: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

impl Histogram {
    /// Exponential bucket edges from `lo` to `hi` (both > 0), `n` buckets.
    pub fn exponential(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n >= 1);
        let ratio = (hi / lo).powf(1.0 / n as f64);
        let mut edges = Vec::with_capacity(n + 1);
        let mut e = lo;
        for _ in 0..=n {
            edges.push(e);
            e *= ratio;
        }
        let len = edges.len() + 1; // underflow + buckets + overflow share counts vec
        Self { edges, counts: vec![0; len], total: 0, sum: 0.0 }
    }

    pub fn record(&mut self, x: f64) {
        // A NaN would land in the overflow bucket AND poison `sum` (and
        // thus `mean`) for the histogram's whole lifetime; ±inf poisons
        // `sum` the same way. Ignore non-finite samples entirely.
        if !x.is_finite() {
            return;
        }
        let idx = match self.edges.iter().position(|&e| x < e) {
            Some(0) => 0,                       // underflow
            Some(i) => i,                       // bucket i-1 maps to counts[i]
            None => self.counts.len() - 1,      // overflow
        };
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += x;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                // counts[0] is underflow -> report lowest edge; last is overflow.
                return if i == 0 {
                    self.edges[0]
                } else if i >= self.edges.len() {
                    *self.edges.last().unwrap()
                } else {
                    self.edges[i - 1]
                };
            }
        }
        *self.edges.last().unwrap()
    }
}

/// Ordinary least squares fit y = a + b·x; returns (a, b).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let b = if den.abs() < 1e-30 { 0.0 } else { num / den };
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.118).abs() < 1e-3);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 4.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
    }

    #[test]
    fn empty_inputs_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(linreg(&[], &[]), (0.0, 0.0));
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = Histogram::exponential(0.1, 1000.0, 32);
        for i in 1..=1000 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 500.5).abs() < 1.0);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 > 100.0 && p50 < 1000.0);
    }

    #[test]
    fn histogram_overflow_underflow() {
        let mut h = Histogram::exponential(1.0, 10.0, 4);
        h.record(0.01);
        h.record(1e9);
        assert_eq!(h.count(), 2);
    }

    /// `percentile` is `pub` and reachable with unfiltered data: NaN must
    /// not panic the sort, and non-finite samples must not shift ranks or
    /// leak into interpolation.
    #[test]
    fn percentile_skips_non_finite_without_panicking() {
        let clean = [10.0, 20.0, 30.0, 40.0];
        let dirty = [
            f64::NAN,
            30.0,
            f64::INFINITY,
            10.0,
            f64::NEG_INFINITY,
            40.0,
            f64::NAN,
            20.0,
        ];
        for q in [0.0, 25.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&dirty, q), percentile(&clean, q), "q={q}");
        }
        // All-non-finite degrades to the empty-input sentinel.
        assert_eq!(percentile(&[f64::NAN, f64::INFINITY], 50.0), 0.0);
    }

    /// The audit companions: `mean`/`std_dev`/`linreg` never panic on
    /// non-finite input (NaN propagates arithmetically, which gated
    /// callers detect via `is_finite`), and min/max skip NaN by `f64`
    /// fold semantics.
    #[test]
    fn moments_and_linreg_tolerate_non_finite() {
        let dirty = [1.0, f64::NAN, 3.0];
        assert!(mean(&dirty).is_nan());
        assert!(std_dev(&dirty).is_nan());
        let (a, b) = linreg(&[0.0, 1.0, 2.0], &[1.0, f64::NAN, 3.0]);
        assert!(a.is_nan() && b.is_nan());
        assert_eq!(min(&dirty), 1.0);
        assert_eq!(max(&dirty), 3.0);
    }

    /// Non-finite samples never poison a histogram's running sum or land
    /// in a bucket.
    #[test]
    fn histogram_ignores_non_finite_samples() {
        let mut h = Histogram::exponential(1.0, 100.0, 8);
        h.record(10.0);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(f64::NEG_INFINITY);
        h.record(10.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.mean(), 10.0);
        assert!(h.quantile(0.99).is_finite());
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }
}

//! Data-parallel helpers over `std::thread::scope` (no rayon offline).
//!
//! The attention engine parallelizes over (head, query-block) work items;
//! these helpers give a simple `parallel_for` with static chunking plus an
//! atomic work-stealing variant for irregular workloads (sparse attention
//! rows have very different costs).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use: `ANCHOR_ATTN_THREADS` env override, else
/// available parallelism, else 4.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("ANCHOR_ATTN_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(i)` for every `i` in `0..n`, dynamically load-balanced across
/// threads (atomic counter hand-out, chunk size 1). `f` must be `Sync` —
/// it borrows shared state; use interior mutability or disjoint outputs.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Like [`parallel_for`] but hands out contiguous chunks of size `chunk` to
/// reduce counter contention for very fine-grained items.
pub fn parallel_for_chunked<F: Fn(usize) + Sync>(n: usize, chunk: usize, f: F) {
    let chunk = chunk.max(1);
    let threads = num_threads().min(n.div_ceil(chunk).max(1));
    if threads <= 1 || n <= chunk {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + chunk).min(n) {
                    f(i);
                }
            });
        }
    });
}

/// Map `0..n` through `f` in parallel, collecting results in order.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<SendPtr<Option<T>>> =
            out.iter_mut().map(|s| SendPtr(s as *mut Option<T>)).collect();
        parallel_for(n, |i| {
            // SAFETY: each index i is visited exactly once; slots are disjoint.
            let p: *mut Option<T> = slots[i].0;
            unsafe {
                *p = Some(f(i));
            }
        });
    }
    out.into_iter().map(|x| x.expect("parallel_map slot unfilled")).collect()
}

/// Raw pointer wrapper asserting cross-thread transfer is safe because the
/// pointed-to slots are disjoint per work item.
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Split a mutable slice into `n` disjoint equal-ish pieces and process them
/// in parallel — the common "each thread owns an output shard" pattern.
pub fn parallel_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    pieces: usize,
    f: F,
) {
    let n = data.len();
    let pieces = pieces.max(1).min(n.max(1));
    if pieces <= 1 {
        f(0, data);
        return;
    }
    let base = n / pieces;
    let rem = n % pieces;
    std::thread::scope(|s| {
        let mut rest = data;
        for p in 0..pieces {
            let len = base + usize::from(p < rem);
            let (head, tail) = rest.split_at_mut(len);
            rest = tail;
            let f = &f;
            s.spawn(move || f(p, head));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_all_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunked_visits_all_once() {
        let hits: Vec<AtomicU64> = (0..517).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunked(517, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(256, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn chunks_mut_covers_slice() {
        let mut data = vec![0u32; 103];
        parallel_chunks_mut(&mut data, 7, |piece, chunk| {
            for x in chunk {
                *x = piece as u32 + 1;
            }
        });
        assert!(data.iter().all(|&x| x >= 1 && x <= 7));
        // Every piece contributed.
        let distinct: std::collections::HashSet<_> = data.iter().collect();
        assert_eq!(distinct.len(), 7);
    }

    #[test]
    fn zero_and_one_items() {
        parallel_for(0, |_| panic!("should not run"));
        let mut ran = false;
        parallel_for(1, |_| {
            // single-item path runs inline
        });
        ran |= true;
        assert!(ran);
        assert_eq!(parallel_map(0, |i| i), Vec::<usize>::new());
    }
}

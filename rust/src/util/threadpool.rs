//! Data-parallel helpers over `std::thread::scope` (no rayon offline).
//!
//! The attention engine parallelizes over (head, query-block) work items;
//! these helpers give a simple `parallel_for` with static chunking plus an
//! atomic work-stealing variant for irregular workloads (sparse attention
//! rows have very different costs).
//!
//! [`OrderedBoundedQueue`] is the substrate of the plan pipeline
//! (DESIGN.md §9): producer workers compute items ahead of a single
//! consumer through a bounded reorder buffer, results delivered in
//! submission order regardless of worker timing, with a poison protocol
//! ([`PoisonOnDrop`]) so a dead worker surfaces an error instead of
//! deadlocking the consumer.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Number of worker threads to use: `ANCHOR_ATTN_THREADS` env override, else
/// available parallelism, else 4.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("ANCHOR_ATTN_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(i)` for every `i` in `0..n`, dynamically load-balanced across
/// threads (atomic counter hand-out, chunk size 1). `f` must be `Sync` —
/// it borrows shared state; use interior mutability or disjoint outputs.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Like [`parallel_for`] but hands out contiguous chunks of size `chunk` to
/// reduce counter contention for very fine-grained items.
pub fn parallel_for_chunked<F: Fn(usize) + Sync>(n: usize, chunk: usize, f: F) {
    let chunk = chunk.max(1);
    let threads = num_threads().min(n.div_ceil(chunk).max(1));
    if threads <= 1 || n <= chunk {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + chunk).min(n) {
                    f(i);
                }
            });
        }
    });
}

/// Map `0..n` through `f` in parallel, collecting results in order.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<SendPtr<Option<T>>> =
            out.iter_mut().map(|s| SendPtr(s as *mut Option<T>)).collect();
        parallel_for(n, |i| {
            // SAFETY: each index i is visited exactly once; slots are disjoint.
            let p: *mut Option<T> = slots[i].0;
            unsafe {
                *p = Some(f(i));
            }
        });
    }
    out.into_iter().map(|x| x.expect("parallel_map slot unfilled")).collect()
}

/// Raw pointer wrapper asserting cross-thread transfer is safe because the
/// pointed-to slots are disjoint per work item.
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Human-readable message from a caught worker panic payload.
pub fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        format!("worker panicked: {s}")
    } else if let Some(s) = e.downcast_ref::<String>() {
        format!("worker panicked: {s}")
    } else {
        "worker panicked".to_string()
    }
}

/// Bounded, order-preserving hand-off between producer workers and one
/// consumer over `n` indexed items.
///
/// Invariants:
/// * **Lookahead bound** — [`OrderedBoundedQueue::claim`] hands out item
///   `i` only once `i < popped + depth`, so at most `depth` items are
///   in flight (computing or queued) ahead of the consumer.
/// * **Deterministic ordering** — [`OrderedBoundedQueue::pop`] yields items
///   strictly in submission (index) order regardless of which worker
///   finishes first.
/// * **No deadlock on failure** — [`OrderedBoundedQueue::poison`] wakes
///   every blocked producer and the consumer; `pop` then reports the error.
pub struct OrderedBoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    /// Producers wait here for lookahead space; poisoning also signals it.
    space: Condvar,
    /// The consumer waits here for the next in-order item.
    ready: Condvar,
    n: usize,
    depth: usize,
}

struct QueueState<T> {
    /// Next item index a producer will claim.
    next_claim: usize,
    /// Next item index the consumer will pop.
    next_pop: usize,
    /// Out-of-order landed results awaiting their turn (≤ depth entries).
    slots: HashMap<usize, T>,
    poisoned: Option<String>,
}

impl<T> OrderedBoundedQueue<T> {
    pub fn new(n: usize, depth: usize) -> Self {
        Self {
            state: Mutex::new(QueueState {
                next_claim: 0,
                next_pop: 0,
                slots: HashMap::new(),
                poisoned: None,
            }),
            space: Condvar::new(),
            ready: Condvar::new(),
            n,
            depth: depth.max(1),
        }
    }

    /// Claim the next work index, blocking while the pipeline is `depth`
    /// items ahead of the consumer. `None` once all work is claimed or the
    /// queue is poisoned.
    pub fn claim(&self) -> Option<usize> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.poisoned.is_some() || st.next_claim >= self.n {
                return None;
            }
            if st.next_claim < st.next_pop + self.depth {
                let i = st.next_claim;
                st.next_claim += 1;
                return Some(i);
            }
            st = self.space.wait(st).unwrap();
        }
    }

    /// Deliver the result for a claimed index. Never blocks: claims are
    /// already lookahead-bounded, so there is always a slot.
    pub fn push(&self, i: usize, value: T) {
        let mut st = self.state.lock().unwrap();
        if st.poisoned.is_some() {
            return;
        }
        debug_assert!(i >= st.next_pop && i < st.next_pop + self.depth, "unclaimed index {i}");
        st.slots.insert(i, value);
        drop(st);
        self.ready.notify_all();
    }

    /// Take the next result in submission order, blocking until it lands.
    /// `Ok(None)` once every item has been popped; `Err` if poisoned.
    pub fn pop(&self) -> Result<Option<(usize, T)>, String> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(msg) = &st.poisoned {
                return Err(msg.clone());
            }
            if st.next_pop >= self.n {
                return Ok(None);
            }
            let i = st.next_pop;
            if let Some(v) = st.slots.remove(&i) {
                st.next_pop += 1;
                drop(st);
                self.space.notify_all();
                return Ok(Some((i, v)));
            }
            st = self.ready.wait(st).unwrap();
        }
    }

    /// Mark the queue failed (first message wins): blocked producers and
    /// the consumer wake and bail instead of deadlocking.
    pub fn poison(&self, msg: String) {
        let mut st = self.state.lock().unwrap();
        if st.poisoned.is_none() {
            st.poisoned = Some(msg);
        }
        drop(st);
        self.space.notify_all();
        self.ready.notify_all();
    }
}

/// Guard that poisons `queue` on drop unless disarmed — keeps producer
/// workers from deadlocking in [`OrderedBoundedQueue::claim`] when the
/// consumer unwinds mid-pipeline.
pub struct PoisonOnDrop<'a, T> {
    pub queue: &'a OrderedBoundedQueue<T>,
    pub armed: bool,
}

impl<T> Drop for PoisonOnDrop<'_, T> {
    fn drop(&mut self) {
        if self.armed {
            self.queue.poison("pipeline consumer aborted".to_string());
        }
    }
}

/// Split a mutable slice into `n` disjoint equal-ish pieces and process them
/// in parallel — the common "each thread owns an output shard" pattern.
pub fn parallel_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    pieces: usize,
    f: F,
) {
    let n = data.len();
    let pieces = pieces.max(1).min(n.max(1));
    if pieces <= 1 {
        f(0, data);
        return;
    }
    let base = n / pieces;
    let rem = n % pieces;
    std::thread::scope(|s| {
        let mut rest = data;
        for p in 0..pieces {
            let len = base + usize::from(p < rem);
            let (head, tail) = rest.split_at_mut(len);
            rest = tail;
            let f = &f;
            s.spawn(move || f(p, head));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_all_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunked_visits_all_once() {
        let hits: Vec<AtomicU64> = (0..517).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunked(517, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(256, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn chunks_mut_covers_slice() {
        let mut data = vec![0u32; 103];
        parallel_chunks_mut(&mut data, 7, |piece, chunk| {
            for x in chunk {
                *x = piece as u32 + 1;
            }
        });
        assert!(data.iter().all(|&x| x >= 1 && x <= 7));
        // Every piece contributed.
        let distinct: std::collections::HashSet<_> = data.iter().collect();
        assert_eq!(distinct.len(), 7);
    }

    #[test]
    fn zero_and_one_items() {
        parallel_for(0, |_| panic!("should not run"));
        let mut ran = false;
        parallel_for(1, |_| {
            // single-item path runs inline
        });
        ran |= true;
        assert!(ran);
        assert_eq!(parallel_map(0, |i| i), Vec::<usize>::new());
    }

    /// `BatchInput` execution and the plan pipeline rely on
    /// `parallel_map` slotting every result at its own index. Items here
    /// deliberately finish out of submission order (early indices sleep),
    /// so any hand-out/ordering bug would scramble the slots.
    #[test]
    fn parallel_map_index_stable_under_contention() {
        let n = 96;
        let v = parallel_map(n, |i| {
            if i % 16 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            } else if i % 3 == 0 {
                std::thread::yield_now();
            }
            i * 31 + 7
        });
        assert_eq!(v.len(), n);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * 31 + 7, "slot {i} holds another item's result");
        }
    }

    /// Results pop in submission order even when producers deliberately
    /// finish out of order.
    #[test]
    fn ordered_queue_delivers_in_submission_order_under_jitter() {
        let queue: OrderedBoundedQueue<usize> = OrderedBoundedQueue::new(33, 2);
        let mut out = Vec::new();
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    while let Some(i) = queue.claim() {
                        if i % 5 == 0 {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        queue.push(i, i * 2);
                    }
                });
            }
            while let Ok(Some((i, v))) = queue.pop() {
                out.push((i, v));
            }
        });
        assert_eq!(out.len(), 33);
        for (k, &(i, v)) in out.iter().enumerate() {
            assert_eq!(k, i, "popped out of submission order");
            assert_eq!(v, i * 2);
        }
    }

    /// Producers never claim more than `depth` items ahead of the
    /// consumer (the two-slot bound the plan pipeline advertises).
    /// Violations poison the queue (panicking in a worker would deadlock
    /// the blocked consumer instead of failing the test).
    #[test]
    fn ordered_queue_bounds_lookahead() {
        let depth = 2;
        let queue: OrderedBoundedQueue<usize> = OrderedBoundedQueue::new(64, depth);
        let consumed = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    while let Some(i) = queue.claim() {
                        // `next_pop` is at most consumed+1 (the item being
                        // handed over), so a claim obeys i <= consumed + depth.
                        let c = consumed.load(Ordering::SeqCst);
                        if i > c + depth {
                            queue.poison(format!("item {i} claimed at {c} consumed"));
                            break;
                        }
                        queue.push(i, i);
                    }
                });
            }
            loop {
                match queue.pop() {
                    Ok(Some(_)) => {
                        consumed.fetch_add(1, Ordering::SeqCst);
                    }
                    Ok(None) => break,
                    Err(msg) => panic!("lookahead bound violated: {msg}"),
                }
            }
        });
        assert_eq!(consumed.load(Ordering::SeqCst), 64);
    }

    /// Poisoning wakes both sides: the consumer gets the message instead
    /// of blocking forever, and blocked producers drain out via `claim`.
    #[test]
    fn poisoned_queue_unblocks_consumer_and_producers() {
        let queue: OrderedBoundedQueue<usize> = OrderedBoundedQueue::new(8, 2);
        let mut popped = 0usize;
        let mut err = None;
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    while let Some(i) = queue.claim() {
                        if i == 3 {
                            queue.poison(format!("producer exploded on item {i}"));
                            break;
                        }
                        queue.push(i, i);
                    }
                });
            }
            loop {
                match queue.pop() {
                    Ok(Some(_)) => popped += 1,
                    Ok(None) => break,
                    Err(msg) => {
                        err = Some(msg);
                        break;
                    }
                }
            }
        });
        let msg = err.expect("consumer must observe the poison");
        assert!(msg.contains("producer exploded"), "{msg}");
        assert!(popped <= 3, "popped {popped} items past the failure");
    }

    #[test]
    fn empty_queue_finishes_immediately() {
        let queue: OrderedBoundedQueue<usize> = OrderedBoundedQueue::new(0, 2);
        assert_eq!(queue.claim(), None);
        assert!(matches!(queue.pop(), Ok(None)));
    }

    #[test]
    fn panic_message_extracts_payload() {
        let e = std::panic::catch_unwind(|| panic!("boom {}", 7)).unwrap_err();
        assert_eq!(panic_message(&*e), "worker panicked: boom 7");
        let e = std::panic::catch_unwind(|| panic!("static boom")).unwrap_err();
        assert_eq!(panic_message(&*e), "worker panicked: static boom");
    }
}

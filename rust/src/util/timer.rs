//! Benchmark timing harness (criterion is unavailable offline).
//!
//! [`BenchRunner`] provides warmup + measured iterations with percentile
//! reporting, used by every target in `rust/benches/` and by the
//! `anchor-attn bench` subcommand.

use std::time::{Duration, Instant};

use crate::util::stats;

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub std_s: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>8} iters  mean {:>10.4} ms  p50 {:>10.4} ms  p95 {:>10.4} ms  min {:>10.4} ms",
            self.name,
            self.iters,
            self.mean_s * 1e3,
            self.p50_s * 1e3,
            self.p95_s * 1e3,
            self.min_s * 1e3
        )
    }
}

/// Warmup-then-measure runner with a wall-clock budget per benchmark.
pub struct BenchRunner {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 3,
            max_iters: 1000,
        }
    }
}

impl BenchRunner {
    /// Fast-mode runner for CI / tests.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(10),
            budget: Duration::from_millis(200),
            min_iters: 2,
            max_iters: 50,
        }
    }

    /// Time `f` repeatedly. The closure's return value is black-boxed to
    /// keep the optimizer honest.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup phase.
        let wstart = Instant::now();
        while wstart.elapsed() < self.warmup {
            black_box(f());
        }
        // Measured phase.
        let mut samples = Vec::new();
        let mstart = Instant::now();
        while (mstart.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_s: stats::mean(&samples),
            p50_s: stats::percentile(&samples, 50.0),
            p95_s: stats::percentile(&samples, 95.0),
            min_s: stats::min(&samples),
            std_s: stats::std_dev(&samples),
        }
    }

    /// Time a single invocation (for expensive end-to-end runs).
    pub fn run_once<T, F: FnOnce() -> T>(&self, name: &str, f: F) -> (BenchResult, T) {
        let t0 = Instant::now();
        let out = black_box(f());
        let dt = t0.elapsed().as_secs_f64();
        (
            BenchResult {
                name: name.to_string(),
                iters: 1,
                mean_s: dt,
                p50_s: dt,
                p95_s: dt,
                min_s: dt,
                std_s: 0.0,
            },
            out,
        )
    }
}

/// Stable `black_box` replacement (avoids nightly-only intrinsics).
#[inline]
pub fn black_box<T>(x: T) -> T {
    // A volatile read of a pointer to x prevents the value from being
    // optimized away without affecting codegen of the computation itself.
    unsafe {
        let ret = std::ptr::read_volatile(&x);
        std::mem::forget(x);
        ret
    }
}

/// Scope timer for coarse phase logging: prints elapsed time on drop when
/// `ANCHOR_ATTN_TRACE=1`.
pub struct ScopeTimer {
    label: &'static str,
    start: Instant,
    enabled: bool,
}

impl ScopeTimer {
    pub fn new(label: &'static str) -> Self {
        let enabled = std::env::var("ANCHOR_ATTN_TRACE").map(|v| v == "1").unwrap_or(false);
        Self { label, start: Instant::now(), enabled }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Drop for ScopeTimer {
    fn drop(&mut self) {
        if self.enabled {
            eprintln!("[trace] {}: {:.3} ms", self.label, self.elapsed_s() * 1e3);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_produces_sane_stats() {
        let r = BenchRunner::quick();
        let res = r.run("noop-ish", || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(res.iters >= 2);
        assert!(res.mean_s > 0.0);
        assert!(res.min_s <= res.mean_s + 1e-12);
        assert!(res.p50_s <= res.p95_s + 1e-12);
    }

    #[test]
    fn run_once_returns_value() {
        let r = BenchRunner::quick();
        let (res, v) = r.run_once("once", || 7 * 6);
        assert_eq!(v, 42);
        assert_eq!(res.iters, 1);
    }

    #[test]
    fn black_box_identity() {
        assert_eq!(black_box(123), 123);
        assert_eq!(black_box(String::from("x")), "x");
    }
}

//! Typed payload codecs for the wire frames (DESIGN.md §14).
//!
//! The protocol exists because plans are *coordinates only*: the reply to
//! a dispatch is a [`SparsePlan`] per fresh key — delta-encoded stripe
//! positions and span runs — plus the per-head output rows. K and V never
//! come back across the wire, and the coordinator never trusts derived
//! quantities: `predicted_cost` is re-priced from the decoded coordinates
//! (deterministic integer tile walk, so the re-derivation is bitwise) and
//! `Coverage` is rebuilt via `plan.coverage()`.
//!
//! **Decode validates before it constructs.** `SparsePlan::new`,
//! `BatchInput::new`, `HeadInput::new` and `Mat::from_vec` all `assert!`
//! their invariants — a panic is the correct response to a caller bug but
//! the wrong response to a corrupted frame. Every decoder here therefore
//! checks the full invariant set (lengths against remaining bytes, group
//! counts against plan geometry, span/stripe ordering, head-shape
//! uniformity) and returns a descriptive `Err` first; the constructors'
//! asserts then re-verify what was already proven.
//!
//! Coordinate compression (§3.4 of the paper makes stripes near-arithmetic,
//! so deltas are small and varints shrink them):
//! * stripes: varint count, varint first value, then varint deltas that
//!   must be ≥ 1 — strict ascent is unrepresentable to violate;
//! * spans: varint count, then per span a varint gap from the previous
//!   span's end and a varint length ≥ 1 — overlap is unrepresentable.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::frame::{Dec, Enc};
use crate::attention::exec::ExecutorKind;
use crate::attention::pipeline::PipelineStats;
use crate::attention::plan::{PlanKey, SparsePlan};
use crate::attention::{anchor, baselines, CostTally, HeadInput, Method, TileConfig};
use crate::tensor::Mat;

// The plan/coordinate delta codec lives in `crate::plan_codec` (shared with
// the segmented plan store — one implementation, wire-stable layout); the
// wire-facing names are re-exported here so peers keep importing them from
// `wire::codec`.
use crate::plan_codec::{get_cost, get_geometry, get_tile, put_cost, put_tile};
pub use crate::plan_codec::{get_plan, put_plan};
#[cfg(test)]
use crate::plan_codec::{get_group, put_group};

// ---------------------------------------------------------------------------
// Status codes and the error envelope
// ---------------------------------------------------------------------------

/// Explicit status of a typed reply. Wire-stable discriminants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StatusCode {
    Ok = 0,
    /// Request failed validation (empty prompt, prompt too long, …).
    Invalid = 1,
    /// Request can never fit the configured pool/sequence budget.
    Oversized = 2,
    /// Admission control shed this request: the queue is at capacity.
    Overloaded = 3,
    /// Accepted but failed during serving.
    Failed = 4,
    /// Peer-side bug or protocol violation.
    Internal = 5,
}

impl StatusCode {
    pub fn from_u8(v: u8) -> Result<StatusCode> {
        Ok(match v {
            0 => StatusCode::Ok,
            1 => StatusCode::Invalid,
            2 => StatusCode::Oversized,
            3 => StatusCode::Overloaded,
            4 => StatusCode::Failed,
            5 => StatusCode::Internal,
            other => return Err(anyhow!("wire: unknown status code {other}")),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            StatusCode::Ok => "ok",
            StatusCode::Invalid => "invalid",
            StatusCode::Oversized => "oversized",
            StatusCode::Overloaded => "overloaded",
            StatusCode::Failed => "failed",
            StatusCode::Internal => "internal",
        }
    }
}

/// Typed failure payload ([`super::frame::FrameKind::Error`] frames and
/// rejected front-end requests).
#[derive(Clone, Debug, PartialEq)]
pub struct ErrorEnvelope {
    pub status: StatusCode,
    pub detail: String,
}

impl ErrorEnvelope {
    pub fn new(status: StatusCode, detail: impl Into<String>) -> Self {
        Self { status, detail: detail.into() }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.u8(self.status as u8);
        e.str(&self.detail);
        e.buf
    }

    pub fn decode(buf: &[u8]) -> Result<ErrorEnvelope> {
        let mut d = Dec::new(buf);
        let status = StatusCode::from_u8(d.u8()?)?;
        let detail = d.str()?;
        d.finish()?;
        Ok(ErrorEnvelope { status, detail })
    }
}

// ---------------------------------------------------------------------------
// Tensors and heads
// ---------------------------------------------------------------------------

fn put_mat(e: &mut Enc, m: &Mat) {
    e.u32(m.rows as u32);
    e.u32(m.cols as u32);
    e.f32_slice(&m.data);
}

fn get_mat(d: &mut Dec) -> Result<Mat> {
    let rows = d.u32()? as usize;
    let cols = d.u32()? as usize;
    let count = rows
        .checked_mul(cols)
        .ok_or_else(|| anyhow!("wire: matrix {rows}×{cols} overflows"))?;
    let bytes = count
        .checked_mul(4)
        .ok_or_else(|| anyhow!("wire: matrix {rows}×{cols} overflows"))?;
    if bytes > d.remaining() {
        return Err(anyhow!(
            "wire: matrix {rows}×{cols} needs {count} f32s but only {} bytes remain",
            d.remaining()
        ));
    }
    Ok(Mat::from_vec(rows, cols, d.f32_vec(count)?))
}

fn put_head(e: &mut Enc, h: &HeadInput) {
    put_mat(e, &h.q);
    put_mat(e, &h.k);
    put_mat(e, &h.v);
}

fn get_head(d: &mut Dec) -> Result<HeadInput> {
    let q = get_mat(d)?;
    let k = get_mat(d)?;
    let v = get_mat(d)?;
    if q.cols != k.cols || k.rows != v.rows || k.cols != v.cols {
        return Err(anyhow!(
            "wire: inconsistent head shapes q {}×{}, k {}×{}, v {}×{}",
            q.rows, q.cols, k.rows, k.cols, v.rows, v.cols
        ));
    }
    Ok(HeadInput::new(q, k, v))
}

fn put_key(e: &mut Enc, k: PlanKey) {
    e.u32(k.layer);
    e.u32(k.head_group);
}

fn get_key(d: &mut Dec) -> Result<PlanKey> {
    Ok(PlanKey { layer: d.u32()?, head_group: d.u32()? })
}

// ---------------------------------------------------------------------------
// Configure
// ---------------------------------------------------------------------------

/// coordinator → worker handshake: which method/executor/pipeline shape
/// this worker must mirror. A worker's session is built from exactly these
/// fields, so thread-shard and process-shard configurations cannot drift.
#[derive(Clone, Debug, PartialEq)]
pub struct ConfigureMsg {
    pub shard_id: u32,
    pub method: Method,
    pub executor: ExecutorKind,
    pub pipelined: bool,
    /// Whether the coordinator runs a shared plan cache (false mirrors
    /// `no_cache` sessions: every head re-identifies).
    pub cache: bool,
}

fn put_method(e: &mut Enc, m: &Method) {
    match m {
        Method::Full(tile) => {
            e.u8(0);
            put_tile(e, *tile);
        }
        Method::Anchor(c) => {
            e.u8(1);
            put_tile(e, c.tile);
            e.f32(c.theta);
            e.varint(c.step as u64);
            e.varint(c.init_blocks as u64);
            e.bool(c.use_anchor);
        }
        Method::Streaming(c) => {
            e.u8(2);
            put_tile(e, c.tile);
            e.varint(c.global_tokens as u64);
            e.varint(c.local_tokens as u64);
        }
        Method::VerticalSlash(c) => {
            e.u8(3);
            put_tile(e, c.tile);
            e.varint(c.vertical_tokens as u64);
            e.varint(c.slash_tokens as u64);
            e.varint(c.last_q as u64);
        }
        Method::FlexPrefill(c) => {
            e.u8(4);
            put_tile(e, c.tile);
            e.f64(c.gamma);
            e.varint(c.min_budget_tokens as u64);
        }
        Method::BlockTopK(c) => {
            e.u8(5);
            put_tile(e, c.tile);
            e.varint(c.k as u64);
            e.bool(c.force_sink_local);
        }
    }
}

fn get_method(d: &mut Dec) -> Result<Method> {
    let variant = d.u8()?;
    Ok(match variant {
        0 => Method::Full(get_tile(d)?),
        1 => {
            let tile = get_tile(d)?;
            let theta = d.f32()?;
            let step = get_geometry(d, "anchor step")?;
            let init_blocks = d.varint()? as usize;
            let use_anchor = d.bool()?;
            Method::Anchor(anchor::AnchorConfig { tile, theta, step, init_blocks, use_anchor })
        }
        2 => {
            let tile = get_tile(d)?;
            let global_tokens = d.varint()? as usize;
            let local_tokens = d.varint()? as usize;
            Method::Streaming(baselines::streaming::StreamingConfig {
                tile,
                global_tokens,
                local_tokens,
            })
        }
        3 => {
            let tile = get_tile(d)?;
            let vertical_tokens = d.varint()? as usize;
            let slash_tokens = d.varint()? as usize;
            let last_q = d.varint()? as usize;
            Method::VerticalSlash(baselines::vertical_slash::VerticalSlashConfig {
                tile,
                vertical_tokens,
                slash_tokens,
                last_q,
            })
        }
        4 => {
            let tile = get_tile(d)?;
            let gamma = d.f64()?;
            let min_budget_tokens = d.varint()? as usize;
            Method::FlexPrefill(baselines::flexprefill::FlexPrefillConfig {
                tile,
                gamma,
                min_budget_tokens,
            })
        }
        5 => {
            let tile = get_tile(d)?;
            let k = d.varint()? as usize;
            let force_sink_local = d.bool()?;
            Method::BlockTopK(baselines::block_topk::BlockTopKConfig { tile, k, force_sink_local })
        }
        other => return Err(anyhow!("wire: unknown method variant {other}")),
    })
}

impl ConfigureMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.u32(self.shard_id);
        put_method(&mut e, &self.method);
        e.str(self.executor.name());
        e.bool(self.pipelined);
        e.bool(self.cache);
        e.buf
    }

    pub fn decode(buf: &[u8]) -> Result<ConfigureMsg> {
        let mut d = Dec::new(buf);
        let shard_id = d.u32()?;
        let method = get_method(&mut d)?;
        let executor = ExecutorKind::parse(&d.str()?)?;
        let pipelined = d.bool()?;
        let cache = d.bool()?;
        d.finish()?;
        Ok(ConfigureMsg { shard_id, method, executor, pipelined, cache })
    }
}

// ---------------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------------

/// One sub-batch for one shard: the heads it owns, their `PlanKey`s, and
/// cache seeds (plans the coordinator already holds for those keys), so
/// the worker's hit/miss accounting lands exactly where a thread worker's
/// would. Q/K/V cross the wire **once, inbound**; only coordinates and
/// output rows come back.
#[derive(Debug)]
pub struct DispatchMsg {
    /// Coordinator-assigned sequence number; the matching reply echoes it.
    pub seq: u64,
    pub keys: Vec<PlanKey>,
    pub seeds: Vec<(PlanKey, Arc<SparsePlan>)>,
    pub heads: Vec<HeadInput>,
}

impl DispatchMsg {
    pub fn encode(&self) -> Vec<u8> {
        let d_head = self.heads.first().map_or(0, |h| h.d());
        let mut e = Enc::new();
        e.u64(self.seq);
        e.u32(self.keys.len() as u32);
        for &k in &self.keys {
            put_key(&mut e, k);
        }
        e.u32(self.seeds.len() as u32);
        for (k, p) in &self.seeds {
            put_key(&mut e, *k);
            put_plan(&mut e, p, d_head);
        }
        e.u32(self.heads.len() as u32);
        for h in &self.heads {
            put_head(&mut e, h);
        }
        e.buf
    }

    pub fn decode(buf: &[u8]) -> Result<DispatchMsg> {
        let mut d = Dec::new(buf);
        let seq = d.u64()?;
        let key_count = d.seq_len(8, "dispatch keys")?;
        let mut keys = Vec::with_capacity(key_count);
        for _ in 0..key_count {
            keys.push(get_key(&mut d)?);
        }
        let seed_count = d.seq_len(8, "dispatch seeds")?;
        let mut seeds = Vec::with_capacity(seed_count);
        for _ in 0..seed_count {
            let k = get_key(&mut d)?;
            seeds.push((k, Arc::new(get_plan(&mut d)?)));
        }
        let head_count = d.seq_len(24, "dispatch heads")?;
        if head_count == 0 {
            return Err(anyhow!("wire: dispatch with no heads"));
        }
        if head_count != key_count {
            return Err(anyhow!(
                "wire: dispatch has {key_count} keys for {head_count} heads"
            ));
        }
        let mut heads = Vec::with_capacity(head_count);
        for _ in 0..head_count {
            heads.push(get_head(&mut d)?);
        }
        let (n, dh) = (heads[0].n(), heads[0].d());
        for h in &heads[1..] {
            if h.n() != n || h.d() != dh {
                return Err(anyhow!(
                    "wire: ragged dispatch batch ({n}×{dh} vs {}×{})",
                    h.n(),
                    h.d()
                ));
            }
        }
        d.finish()?;
        Ok(DispatchMsg { seq, keys, seeds, heads })
    }
}

// ---------------------------------------------------------------------------
// Reply
// ---------------------------------------------------------------------------

/// Worker → coordinator result for one dispatch. Plans are deduplicated:
/// `plan_of[h]` indexes into `plans`, so a key group's shared plan crosses
/// the wire once. `Coverage` is never transmitted — the coordinator rebuilds
/// it from the decoded plan's coordinates.
#[derive(Debug)]
pub struct ReplyMsg {
    pub seq: u64,
    /// Per-head output rows and execution cost (ident already folded in,
    /// exactly as a thread worker reports them).
    pub outs: Vec<(Mat, CostTally)>,
    /// Plan index per head, into `plans`.
    pub plan_of: Vec<u32>,
    pub plans: Vec<Arc<SparsePlan>>,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub ident_paid: CostTally,
    pub pipeline: Option<PipelineStats>,
}

fn put_pipeline(e: &mut Enc, p: &PipelineStats) {
    e.f64(p.ident_total_s);
    e.f64(p.ident_hidden_s);
    e.f64(p.exec_total_s);
    e.f64(p.stall_s);
    e.f64(p.wall_s);
    e.u64(p.items as u64);
}

fn get_pipeline(d: &mut Dec) -> Result<PipelineStats> {
    Ok(PipelineStats {
        ident_total_s: d.f64()?,
        ident_hidden_s: d.f64()?,
        exec_total_s: d.f64()?,
        stall_s: d.f64()?,
        wall_s: d.f64()?,
        items: d.u64()? as usize,
    })
}

impl ReplyMsg {
    pub fn encode(&self, d_head: usize) -> Vec<u8> {
        let mut e = Enc::new();
        e.u64(self.seq);
        e.u32(self.outs.len() as u32);
        for (m, c) in &self.outs {
            put_mat(&mut e, m);
            put_cost(&mut e, *c);
        }
        for &i in &self.plan_of {
            e.u32(i);
        }
        e.u32(self.plans.len() as u32);
        for p in &self.plans {
            put_plan(&mut e, p, d_head);
        }
        e.u64(self.cache_hits);
        e.u64(self.cache_misses);
        put_cost(&mut e, self.ident_paid);
        match &self.pipeline {
            Some(p) => {
                e.bool(true);
                put_pipeline(&mut e, p);
            }
            None => e.bool(false),
        }
        e.buf
    }

    pub fn decode(buf: &[u8]) -> Result<ReplyMsg> {
        let mut d = Dec::new(buf);
        let seq = d.u64()?;
        let h = d.seq_len(32, "reply outputs")?;
        let mut outs = Vec::with_capacity(h);
        for _ in 0..h {
            let m = get_mat(&mut d)?;
            let c = get_cost(&mut d)?;
            outs.push((m, c));
        }
        let mut plan_of = Vec::with_capacity(h);
        for _ in 0..h {
            plan_of.push(d.u32()?);
        }
        let plan_count = d.seq_len(1, "reply plans")?;
        let mut plans = Vec::with_capacity(plan_count);
        for _ in 0..plan_count {
            plans.push(Arc::new(get_plan(&mut d)?));
        }
        for &i in &plan_of {
            if i as usize >= plans.len() {
                return Err(anyhow!(
                    "wire: reply plan index {i} out of range ({plan_count} plans)"
                ));
            }
        }
        let cache_hits = d.u64()?;
        let cache_misses = d.u64()?;
        let ident_paid = get_cost(&mut d)?;
        let pipeline = if d.bool()? { Some(get_pipeline(&mut d)?) } else { None };
        d.finish()?;
        Ok(ReplyMsg {
            seq,
            outs,
            plan_of,
            plans,
            cache_hits,
            cache_misses,
            ident_paid,
            pipeline,
        })
    }
}

// ---------------------------------------------------------------------------
// Front-end request envelope
// ---------------------------------------------------------------------------

/// Wire form of a serve submission ([`super::frame::FrameKind::ReqSubmit`]).
/// Mirrors `coordinator::server::ServeRequest` field-for-field.
#[derive(Clone, Debug, PartialEq)]
pub struct ReqSubmitMsg {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: u64,
    pub arrival_s: f64,
}

impl ReqSubmitMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.u64(self.id);
        e.u32(self.prompt.len() as u32);
        for &t in &self.prompt {
            e.u32(t as u32);
        }
        e.u64(self.max_new_tokens);
        e.f64(self.arrival_s);
        e.buf
    }

    pub fn decode(buf: &[u8]) -> Result<ReqSubmitMsg> {
        let mut d = Dec::new(buf);
        let id = d.u64()?;
        let count = d.seq_len(4, "prompt tokens")?;
        let mut prompt = Vec::with_capacity(count);
        for _ in 0..count {
            prompt.push(d.u32()? as i32);
        }
        let max_new_tokens = d.u64()?;
        let arrival_s = d.f64()?;
        d.finish()?;
        Ok(ReqSubmitMsg { id, prompt, max_new_tokens, arrival_s })
    }
}

/// Admission verdict for one submission
/// ([`super::frame::FrameKind::ReqReply`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ReqReplyMsg {
    pub id: u64,
    pub status: StatusCode,
    pub detail: String,
}

impl ReqReplyMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.u64(self.id);
        e.u8(self.status as u8);
        e.str(&self.detail);
        e.buf
    }

    pub fn decode(buf: &[u8]) -> Result<ReqReplyMsg> {
        let mut d = Dec::new(buf);
        let id = d.u64()?;
        let status = StatusCode::from_u8(d.u8()?)?;
        let detail = d.str()?;
        d.finish()?;
        Ok(ReqReplyMsg { id, status, detail })
    }
}

/// Health endpoint reply: queue depth against capacity (0 = unbounded).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HealthReplyMsg {
    pub queued: u64,
    pub capacity: u64,
}

impl HealthReplyMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.u64(self.queued);
        e.u64(self.capacity);
        e.buf
    }

    pub fn decode(buf: &[u8]) -> Result<HealthReplyMsg> {
        let mut d = Dec::new(buf);
        let msg = HealthReplyMsg { queued: d.u64()?, capacity: d.u64()? };
        d.finish()?;
        Ok(msg)
    }
}

/// Metrics endpoint reply: a JSON document (the serve report summary).
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsReplyMsg {
    pub json: String,
}

impl MetricsReplyMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.str(&self.json);
        e.buf
    }

    pub fn decode(buf: &[u8]) -> Result<MetricsReplyMsg> {
        let mut d = Dec::new(buf);
        let msg = MetricsReplyMsg { json: d.str()? };
        d.finish()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::plan::GroupPlan;
    use crate::tensor::Mat;
    use crate::util::rng::Pcg64;

    fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
        let mut rng = Pcg64::seeded(seed);
        HeadInput::new(
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
            Mat::from_fn(n, d, |_, _| rng.normal()),
        )
    }

    fn anchor_method() -> Method {
        Method::Anchor(anchor::AnchorConfig {
            tile: TileConfig::new(16, 16),
            theta: 4.0,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        })
    }

    fn all_methods() -> Vec<Method> {
        let tile = TileConfig::new(16, 16);
        vec![
            Method::Full(tile),
            anchor_method(),
            Method::Streaming(baselines::streaming::StreamingConfig {
                tile,
                global_tokens: 16,
                local_tokens: 32,
            }),
            Method::VerticalSlash(baselines::vertical_slash::VerticalSlashConfig {
                tile,
                vertical_tokens: 8,
                slash_tokens: 8,
                last_q: 16,
            }),
            Method::FlexPrefill(baselines::flexprefill::FlexPrefillConfig {
                tile,
                gamma: 0.9,
                min_budget_tokens: 16,
            }),
            Method::BlockTopK(baselines::block_topk::BlockTopKConfig {
                tile,
                k: 3,
                force_sink_local: true,
            }),
        ]
    }

    #[test]
    fn every_method_config_round_trips() {
        for m in all_methods() {
            let msg = ConfigureMsg {
                shard_id: 3,
                method: m,
                executor: ExecutorKind::Cpu,
                pipelined: true,
                cache: false,
            };
            let back = ConfigureMsg::decode(&msg.encode()).unwrap();
            assert_eq!(back, msg);
        }
    }

    #[test]
    fn real_plans_round_trip_bitwise_for_all_planners() {
        let h = rand_head(7, 192, 16);
        for m in all_methods() {
            let plan = m.plan(&h);
            let mut e = Enc::new();
            put_plan(&mut e, &plan, h.d());
            let mut d = Dec::new(&e.buf);
            let back = get_plan(&mut d).unwrap();
            d.finish().unwrap();
            // PartialEq covers coordinates, ident_cost, and the re-derived
            // predicted_cost — the quantity the wire never transmits.
            assert_eq!(back, plan, "{}", m.name());
        }
    }

    #[test]
    fn corrupted_plan_coordinates_are_rejected_not_panicked() {
        let h = rand_head(8, 64, 8);
        let plan = anchor_method().plan(&h);
        let mut e = Enc::new();
        put_plan(&mut e, &plan, 8);
        let clean = e.buf.clone();
        // Every single-byte corruption either still decodes to *some* valid
        // plan or errors — it must never panic.
        for i in 0..clean.len() {
            let mut bad = clean.clone();
            bad[i] ^= 0x41;
            let mut d = Dec::new(&bad);
            let _ = get_plan(&mut d); // must not panic
        }
        // Truncations likewise.
        for cut in 0..clean.len() {
            let mut d = Dec::new(&clean[..cut]);
            assert!(get_plan(&mut d).is_err(), "truncation at {cut} decoded");
        }
    }

    #[test]
    fn plan_with_wrong_group_count_is_rejected() {
        // Hand-encode a plan whose geometry demands 2 groups but carries 0
        // bytes of them.
        let mut e = Enc::new();
        e.str("anchor");
        e.varint(64); // n → 4 q-blocks
        e.varint(8); // d
        e.varint(16);
        e.varint(16); // tile
        e.varint(2); // step → 2 groups
        put_cost(&mut e, CostTally::default());
        let mut d = Dec::new(&e.buf);
        assert!(get_plan(&mut d).is_err());
    }

    #[test]
    fn unknown_method_name_is_a_corruption_signal() {
        let h = rand_head(9, 32, 4);
        let plan = Method::Full(TileConfig::new(16, 16)).plan(&h);
        let mut e = Enc::new();
        put_plan(&mut e, &plan, 4);
        // Overwrite the method string "full-attn" in place (it is the first
        // field: u32 len + bytes).
        e.buf[4..13].copy_from_slice(b"full-bttn");
        let mut d = Dec::new(&e.buf);
        let err = get_plan(&mut d).unwrap_err().to_string();
        assert!(err.contains("full-bttn"), "{err}");
    }

    #[test]
    fn dispatch_round_trips_with_seeds() {
        let h0 = rand_head(10, 64, 8);
        let h1 = rand_head(11, 64, 8);
        let key = PlanKey::new(0, 0);
        let plan = Arc::new(anchor_method().plan(&h0));
        let msg = DispatchMsg {
            seq: 42,
            keys: vec![key, PlanKey::new(0, 1)],
            seeds: vec![(key, plan.clone())],
            heads: vec![h0.clone(), h1.clone()],
        };
        let back = DispatchMsg::decode(&msg.encode()).unwrap();
        assert_eq!(back.seq, 42);
        assert_eq!(back.keys, msg.keys);
        assert_eq!(back.seeds.len(), 1);
        assert_eq!(*back.seeds[0].1, *plan);
        assert_eq!(back.heads.len(), 2);
        // Tensor payloads are bitwise.
        assert_eq!(back.heads[0].q.data, h0.q.data);
        assert_eq!(back.heads[1].v.data, h1.v.data);
    }

    #[test]
    fn dispatch_key_head_mismatch_rejected() {
        let h = rand_head(12, 32, 4);
        let msg =
            DispatchMsg { seq: 1, keys: vec![PlanKey::new(0, 0)], seeds: vec![], heads: vec![h] };
        let mut buf = msg.encode();
        // Append nothing; instead corrupt the key count to 0.
        buf[8..12].copy_from_slice(&0u32.to_le_bytes());
        assert!(DispatchMsg::decode(&buf).is_err());
    }

    #[test]
    fn reply_round_trips_bitwise() {
        let h = rand_head(13, 96, 8);
        let plan = Arc::new(anchor_method().plan(&h));
        let out = crate::attention::plan::execute_plan(&h, &plan);
        let msg = ReplyMsg {
            seq: 7,
            outs: vec![(out.out.clone(), out.cost)],
            plan_of: vec![0],
            plans: vec![plan.clone()],
            cache_hits: 2,
            cache_misses: 1,
            ident_paid: plan.ident_cost,
            pipeline: Some(PipelineStats {
                ident_total_s: 0.5,
                ident_hidden_s: 0.25,
                exec_total_s: 1.0,
                stall_s: 0.25,
                wall_s: 1.25,
                items: 3,
            }),
        };
        let back = ReplyMsg::decode(&msg.encode(h.d())).unwrap();
        assert_eq!(back.seq, 7);
        assert_eq!(back.outs[0].0.data, out.out.data); // bitwise rows
        assert_eq!(back.outs[0].1, out.cost);
        assert_eq!(*back.plans[0], *plan);
        assert_eq!((back.cache_hits, back.cache_misses), (2, 1));
        assert_eq!(back.ident_paid, plan.ident_cost);
        assert_eq!(back.pipeline.unwrap().items, 3);
    }

    #[test]
    fn reply_with_dangling_plan_index_rejected() {
        let h = rand_head(14, 32, 4);
        let plan = Arc::new(Method::Full(TileConfig::new(16, 16)).plan(&h));
        let out = crate::attention::plan::execute_plan(&h, &plan);
        let msg = ReplyMsg {
            seq: 1,
            outs: vec![(out.out, out.cost)],
            plan_of: vec![5], // out of range
            plans: vec![plan],
            cache_hits: 0,
            cache_misses: 1,
            ident_paid: CostTally::default(),
            pipeline: None,
        };
        assert!(ReplyMsg::decode(&msg.encode(4)).is_err());
    }

    #[test]
    fn front_end_envelopes_round_trip() {
        let req = ReqSubmitMsg {
            id: 9,
            prompt: vec![1, 2, 3, -4],
            max_new_tokens: 16,
            arrival_s: 0.5,
        };
        assert_eq!(ReqSubmitMsg::decode(&req.encode()).unwrap(), req);
        let rep = ReqReplyMsg {
            id: 9,
            status: StatusCode::Overloaded,
            detail: "queue at capacity".into(),
        };
        assert_eq!(ReqReplyMsg::decode(&rep.encode()).unwrap(), rep);
        let health = HealthReplyMsg { queued: 3, capacity: 8 };
        assert_eq!(HealthReplyMsg::decode(&health.encode()).unwrap(), health);
        let metrics = MetricsReplyMsg { json: "{\"requests\": 3}".into() };
        assert_eq!(MetricsReplyMsg::decode(&metrics.encode()).unwrap(), metrics);
        let env = ErrorEnvelope::new(StatusCode::Internal, "boom");
        assert_eq!(ErrorEnvelope::decode(&env.encode()).unwrap(), env);
    }

    #[test]
    fn delta_encoding_is_compact_for_arithmetic_stripes() {
        // §3.4: stripes are near-arithmetic, so deltas are small and the
        // varint coding should beat 4-bytes-per-coordinate by a wide margin.
        let stripes: Vec<u32> = (0..1000u32).map(|i| 100 + 3 * i).collect();
        let g = GroupPlan { spans: vec![(0, 16)], stripes };
        let mut e = Enc::new();
        put_group(&mut e, &g);
        assert!(
            e.buf.len() < 2 + 1002 * 2,
            "delta coding took {} bytes for 1000 stripes",
            e.buf.len()
        );
        let mut d = Dec::new(&e.buf);
        let back = get_group(&mut d, 4096).unwrap();
        assert_eq!(back, g);
    }
}

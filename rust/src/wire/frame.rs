//! Binary frame layer of the coordinate-only wire protocol (DESIGN.md §14).
//!
//! Every message on a shard or front-end connection is one frame:
//!
//! ```text
//! offset  size  field
//!      0     4  magic    0x414E4B52 ("ANKR", big-endian byte order on the
//!                        wire so a hexdump reads the tag)
//!      4     2  version  WIRE_VERSION, little-endian
//!      6     2  kind     FrameKind discriminant, little-endian
//!      8     4  length   payload byte count, little-endian
//!     12     …  payload  kind-specific body (see [`super::codec`])
//! ```
//!
//! The version rule mirrors the manifest stores (DESIGN.md §11/§13): a
//! frame whose magic, version, or kind is unknown — or whose declared
//! length exceeds [`MAX_FRAME_BYTES`] — is **rejected with a descriptive
//! error, never reinterpreted**. Peers on different protocol versions must
//! fail loudly at the first frame, not corrupt tensors silently.
//!
//! Payload primitives are little-endian fixed-width integers, raw IEEE-754
//! bit patterns for floats (`f32::to_le_bytes` / `from_le_bytes`, so
//! tensors round-trip **bitwise** — the shard parity wall depends on it),
//! and LEB128 varints for the delta-encoded plan coordinates. Every length
//! read by [`Dec`] is validated against the bytes actually remaining
//! before any allocation, so a corrupted or hostile length field cannot
//! trigger an over-allocation or a panic.

use std::io::{Read, Write};

use anyhow::{anyhow, Result};

/// Frame tag: "ANKR" as big-endian bytes on the wire.
pub const WIRE_MAGIC: u32 = 0x414E_4B52;
/// Protocol version. Bump on any payload layout change; peers reject
/// mismatches loudly (never reinterpret).
pub const WIRE_VERSION: u16 = 1;
/// Upper bound on one frame's payload. Generous for sub-batch tensor
/// dispatch (a 5-head 32k×128 f32 batch is ~250 MiB is far beyond any grid
/// this repo runs; typical frames are KiB–MiB), tight enough that a
/// corrupted length field cannot drive a giant allocation.
pub const MAX_FRAME_BYTES: usize = 256 << 20;
/// Fixed header size: magic + version + kind + length.
pub const HEADER_BYTES: usize = 12;

/// Every frame type the protocol speaks. Discriminants are wire-stable:
/// never reuse a retired value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// coordinator → worker: method/executor/pipeline configuration.
    Configure = 1,
    /// worker → coordinator: configuration accepted, ready for dispatch.
    Ready = 2,
    /// coordinator → worker: one sub-batch (keys + Q/K/V heads + seeds).
    Dispatch = 3,
    /// worker → coordinator: outputs + plan coordinates for one dispatch.
    Reply = 4,
    /// Either direction: typed failure ([`super::codec::ErrorEnvelope`]).
    Error = 5,
    /// Liveness probe / answer.
    Ping = 6,
    Pong = 7,
    /// coordinator → worker: exit cleanly.
    Shutdown = 8,
    /// client → front-end: submit one typed serve request.
    ReqSubmit = 9,
    /// front-end → client: admission verdict for one request.
    ReqReply = 10,
    /// client → front-end: health endpoint.
    Health = 11,
    HealthReply = 12,
    /// client → front-end: metrics endpoint.
    Metrics = 13,
    MetricsReply = 14,
}

impl FrameKind {
    pub fn from_u16(v: u16) -> Result<FrameKind> {
        Ok(match v {
            1 => FrameKind::Configure,
            2 => FrameKind::Ready,
            3 => FrameKind::Dispatch,
            4 => FrameKind::Reply,
            5 => FrameKind::Error,
            6 => FrameKind::Ping,
            7 => FrameKind::Pong,
            8 => FrameKind::Shutdown,
            9 => FrameKind::ReqSubmit,
            10 => FrameKind::ReqReply,
            11 => FrameKind::Health,
            12 => FrameKind::HealthReply,
            13 => FrameKind::Metrics,
            14 => FrameKind::MetricsReply,
            other => return Err(anyhow!("wire: unknown frame kind {other}")),
        })
    }
}

/// Serialize one frame into a fresh buffer (header + payload).
pub fn encode_frame(kind: FrameKind, payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_FRAME_BYTES, "frame payload over MAX_FRAME_BYTES");
    let mut buf = Vec::with_capacity(HEADER_BYTES + payload.len());
    buf.extend_from_slice(&WIRE_MAGIC.to_be_bytes());
    buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    buf.extend_from_slice(&(kind as u16).to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(payload);
    buf
}

/// Write one frame to a stream.
pub fn write_frame(w: &mut impl Write, kind: FrameKind, payload: &[u8]) -> Result<()> {
    let buf = encode_frame(kind, payload);
    w.write_all(&buf).map_err(|e| anyhow!("wire: write failed: {e}"))?;
    w.flush().map_err(|e| anyhow!("wire: flush failed: {e}"))?;
    Ok(())
}

/// Validate a frame header; returns `(kind, payload_len)`.
fn parse_header(h: &[u8; HEADER_BYTES]) -> Result<(FrameKind, usize)> {
    let magic = u32::from_be_bytes([h[0], h[1], h[2], h[3]]);
    if magic != WIRE_MAGIC {
        return Err(anyhow!("wire: bad frame magic {magic:#010x} (expected {WIRE_MAGIC:#010x})"));
    }
    let version = u16::from_le_bytes([h[4], h[5]]);
    if version != WIRE_VERSION {
        return Err(anyhow!(
            "wire: protocol version {version} does not match this build's {WIRE_VERSION} — \
             versions are rejected, never reinterpreted"
        ));
    }
    let kind = FrameKind::from_u16(u16::from_le_bytes([h[6], h[7]]))?;
    let len = u32::from_le_bytes([h[8], h[9], h[10], h[11]]) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(anyhow!(
            "wire: declared payload of {len} bytes exceeds the {MAX_FRAME_BYTES}-byte frame cap"
        ));
    }
    Ok((kind, len))
}

/// Read one frame from a stream (blocking; honors the stream's read
/// timeout — a deadline expiry surfaces as an `Err`, never a hang).
pub fn read_frame(r: &mut impl Read) -> Result<(FrameKind, Vec<u8>)> {
    let mut header = [0u8; HEADER_BYTES];
    r.read_exact(&mut header).map_err(|e| anyhow!("wire: read failed: {e}"))?;
    let (kind, len) = parse_header(&header)?;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|e| anyhow!("wire: truncated {kind:?} frame ({len} byte payload): {e}"))?;
    Ok((kind, payload))
}

/// As [`read_frame`], but a clean end-of-stream at the frame boundary is
/// `Ok(None)` — the worker's accept loop treats a peer hangup as "back to
/// accept", not an error. EOF *inside* a frame is still corruption-loud.
pub fn read_frame_opt(r: &mut impl Read) -> Result<Option<(FrameKind, Vec<u8>)>> {
    let mut header = [0u8; HEADER_BYTES];
    if let Err(e) = r.read_exact(&mut header) {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            return Ok(None);
        }
        return Err(anyhow!("wire: read failed: {e}"));
    }
    let (kind, len) = parse_header(&header)?;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|e| anyhow!("wire: truncated {kind:?} frame ({len} byte payload): {e}"))?;
    Ok(Some((kind, payload)))
}

/// Decode one frame from an in-memory buffer (the fuzz/property-test
/// entry). Rejects trailing bytes: a frame is exactly header + payload.
pub fn decode_frame_bytes(buf: &[u8]) -> Result<(FrameKind, &[u8])> {
    if buf.len() < HEADER_BYTES {
        return Err(anyhow!(
            "wire: {} bytes is shorter than the {HEADER_BYTES}-byte frame header",
            buf.len()
        ));
    }
    let mut header = [0u8; HEADER_BYTES];
    header.copy_from_slice(&buf[..HEADER_BYTES]);
    let (kind, len) = parse_header(&header)?;
    let body = &buf[HEADER_BYTES..];
    if body.len() != len {
        return Err(anyhow!(
            "wire: declared payload of {len} bytes but {} present",
            body.len()
        ));
    }
    Ok((kind, body))
}

/// Payload encoder: fixed-width little-endian primitives + LEB128 varints.
#[derive(Default)]
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(u8::from(v));
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Raw IEEE-754 bits — the bitwise-parity-preserving float encoding.
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// LEB128 varint — the delta-coordinate encoding.
    pub fn varint(&mut self, mut v: u64) {
        loop {
            let byte = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                return;
            }
            self.buf.push(byte | 0x80);
        }
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// A whole f32 slice as raw little-endian bits.
    pub fn f32_slice(&mut self, xs: &[f32]) {
        self.buf.reserve(xs.len() * 4);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Payload decoder over a borrowed buffer. Every accessor validates the
/// remaining byte count before touching the buffer, so corrupted frames
/// produce descriptive `Err`s instead of panics or over-allocations.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(anyhow!(
                "wire: truncated payload at byte {}: {what} needs {n} bytes, {} remain",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1, "u8")?[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(anyhow!("wire: bool byte must be 0 or 1, got {other}")),
        }
    }

    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2, "u16")?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4, "u32")?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8, "u64")?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f32(&mut self) -> Result<f32> {
        let b = self.take(4, "f32")?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn f64(&mut self) -> Result<f64> {
        let b = self.take(8, "f64")?;
        Ok(f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn varint(&mut self) -> Result<u64> {
        let mut v: u64 = 0;
        for shift in (0..64).step_by(7) {
            let byte = self.take(1, "varint")?[0];
            v |= u64::from(byte & 0x7f) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
        }
        Err(anyhow!("wire: varint longer than 10 bytes at byte {}", self.pos))
    }

    /// Read a `u32` element count and verify `count * elem_bytes` fits in
    /// the remaining payload **before** any allocation.
    pub fn seq_len(&mut self, elem_bytes: usize, what: &str) -> Result<usize> {
        let count = self.u32()? as usize;
        let need = count.checked_mul(elem_bytes).ok_or_else(|| {
            anyhow!("wire: {what} count {count} overflows the frame size")
        })?;
        if need > self.remaining() {
            return Err(anyhow!(
                "wire: {what} declares {count} elements ({need} bytes) but only {} bytes remain",
                self.remaining()
            ));
        }
        Ok(count)
    }

    pub fn str(&mut self) -> Result<String> {
        let len = self.seq_len(1, "string")?;
        let bytes = self.take(len, "string bytes")?;
        String::from_utf8(bytes.to_vec())
            .map_err(|e| anyhow!("wire: invalid utf-8 in string: {e}"))
    }

    pub fn f32_vec(&mut self, count: usize) -> Result<Vec<f32>> {
        let bytes = self.take(count * 4, "f32 data")?;
        let mut out = Vec::with_capacity(count);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(out)
    }

    /// Payloads must be fully consumed — trailing bytes mean the peer and
    /// this build disagree on the layout, which the version field should
    /// have caught; reject rather than guess.
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(anyhow!(
                "wire: {} unconsumed payload byte(s) after decode — layout mismatch",
                self.remaining()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_round_trips_through_bytes() {
        let buf = encode_frame(FrameKind::Ping, b"hello");
        let (kind, body) = decode_frame_bytes(&buf).unwrap();
        assert_eq!(kind, FrameKind::Ping);
        assert_eq!(body, b"hello");
    }

    #[test]
    fn frame_round_trips_through_a_stream() {
        let mut stream: Vec<u8> = Vec::new();
        write_frame(&mut stream, FrameKind::Reply, &[1, 2, 3]).unwrap();
        write_frame(&mut stream, FrameKind::Shutdown, &[]).unwrap();
        let mut r = std::io::Cursor::new(stream);
        let (k1, p1) = read_frame(&mut r).unwrap();
        let (k2, p2) = read_frame(&mut r).unwrap();
        assert_eq!((k1, p1.as_slice()), (FrameKind::Reply, &[1u8, 2, 3][..]));
        assert_eq!((k2, p2.len()), (FrameKind::Shutdown, 0));
    }

    #[test]
    fn wrong_version_is_rejected_loudly() {
        let mut buf = encode_frame(FrameKind::Ping, &[]);
        buf[4] = WIRE_VERSION as u8 + 1;
        let err = decode_frame_bytes(&buf).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn bad_magic_and_bad_kind_are_rejected() {
        let mut buf = encode_frame(FrameKind::Ping, &[]);
        buf[0] ^= 0xff;
        assert!(decode_frame_bytes(&buf).unwrap_err().to_string().contains("magic"));
        let mut buf = encode_frame(FrameKind::Ping, &[]);
        buf[6] = 0xee;
        assert!(decode_frame_bytes(&buf).unwrap_err().to_string().contains("kind"));
    }

    #[test]
    fn over_length_declaration_is_rejected_before_allocation() {
        let mut buf = encode_frame(FrameKind::Ping, &[]);
        buf[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_frame_bytes(&buf).unwrap_err().to_string();
        assert!(err.contains("cap"), "{err}");
    }

    #[test]
    fn primitives_round_trip() {
        let mut e = Enc::new();
        e.u8(7);
        e.bool(true);
        e.u16(300);
        e.u32(70_000);
        e.u64(1 << 40);
        e.f32(-0.0);
        e.f64(std::f64::consts::PI);
        e.varint(0);
        e.varint(127);
        e.varint(128);
        e.varint(u64::MAX);
        e.str("stripe");
        e.f32_slice(&[1.5, -2.5]);
        let mut d = Dec::new(&e.buf);
        assert_eq!(d.u8().unwrap(), 7);
        assert!(d.bool().unwrap());
        assert_eq!(d.u16().unwrap(), 300);
        assert_eq!(d.u32().unwrap(), 70_000);
        assert_eq!(d.u64().unwrap(), 1 << 40);
        assert_eq!(d.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(d.f64().unwrap(), std::f64::consts::PI);
        assert_eq!(d.varint().unwrap(), 0);
        assert_eq!(d.varint().unwrap(), 127);
        assert_eq!(d.varint().unwrap(), 128);
        assert_eq!(d.varint().unwrap(), u64::MAX);
        assert_eq!(d.str().unwrap(), "stripe");
        assert_eq!(d.f32_vec(2).unwrap(), vec![1.5, -2.5]);
        d.finish().unwrap();
    }

    #[test]
    fn truncated_reads_error_instead_of_panicking() {
        let mut d = Dec::new(&[1, 2]);
        assert!(d.u32().is_err());
        let mut d = Dec::new(&[0x80, 0x80]);
        assert!(d.varint().is_err());
        // A declared length far past the buffer is caught before allocation.
        let mut e = Enc::new();
        e.u32(u32::MAX);
        let mut d = Dec::new(&e.buf);
        assert!(d.str().is_err());
    }

    #[test]
    fn trailing_bytes_fail_finish() {
        let d = Dec::new(&[1]);
        assert!(d.finish().is_err());
    }
}

//! Coordinate-only wire protocol (DESIGN.md §14): the first multi-process
//! subsystem in the repo.
//!
//! The architecture's core invariant — shards exchange **plan coordinates,
//! never K/V** (DESIGN.md §12) — is exactly what makes sharding viable
//! across a process or machine boundary: a dispatch ships each head's
//! Q/K/V to one worker once, and everything that comes back or is shared
//! afterwards is discrete stripe/span coordinates (§3.2–§3.3 of the
//! paper), delta-encoded into a few bytes per coordinate.
//!
//! Layers, bottom-up:
//! * [`frame`] — length-prefixed, versioned, magic-tagged binary frames;
//!   unknown versions/kinds/lengths are rejected loudly, never
//!   reinterpreted (the manifest stores' version rule, applied to a
//!   socket).
//! * [`codec`] — typed payloads: Configure/Dispatch/Reply for shard
//!   traffic, request/health/metrics envelopes for the serve front-end.
//!   Decoders validate everything before constructing (the repo's
//!   assert-heavy types must never panic on corrupt input).
//! * [`worker`] — the `anchor-attn worker` serve loop: stateless across
//!   dispatches, seeded per dispatch, loud on failure.
//! * [`transport`] — [`transport::RemoteShard`]: spawned-child or
//!   TCP/UDS endpoints with connect/read deadlines and
//!   reconnect-with-backoff at batch boundaries.
//!
//! `ShardedSession` plugs in at `ShardedSessionBuilder::remote`, keeping
//! one merge/accounting path: sharded-over-wire output is bitwise-equal to
//! sharded-over-threads (gated by `tests/wire_parity.rs` and CI's
//! `wire-parity` job).

pub mod codec;
pub mod frame;
pub mod transport;
pub mod worker;

pub use codec::{ErrorEnvelope, StatusCode};
pub use transport::{RemoteSpec, ShardEndpoint, WireTimeouts};

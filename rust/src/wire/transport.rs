//! The coordinator side of the wire: [`RemoteShard`] owns one shard
//! worker's connection, whether that worker is a child process this
//! coordinator spawned or a pre-started TCP/UDS endpoint.
//!
//! Failure contract (mirrors the thread path's "failure is loud"
//! invariant, DESIGN.md §12): an I/O error, deadline expiry, or worker
//! `Error` frame **mid-batch** drops the connection and surfaces
//! immediately as an `Err` — there is no silent in-batch retry that could
//! mask a crashed worker. Reconnect-with-backoff happens at the *next*
//! batch's `ensure_connected`, which (in spawn mode) also respawns a dead
//! child; a subsequent batch on a recovered or surviving worker therefore
//! succeeds without the caller doing anything.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::codec::{ConfigureMsg, DispatchMsg, ErrorEnvelope, ReplyMsg};
use super::frame::{read_frame, write_frame, FrameKind};

/// Where one pre-started shard worker listens.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardEndpoint {
    /// TCP address, e.g. `"127.0.0.1:7401"`.
    Tcp(String),
    /// Unix domain socket path.
    Uds(PathBuf),
}

/// How a sharded session reaches its remote workers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RemoteSpec {
    /// Spawn one child process per shard (`<program> worker --uds <sock>`).
    /// `None` runs the current executable — the production shape for the
    /// `anchor-attn` binary.
    Spawn { program: Option<PathBuf> },
    /// Connect to pre-started workers; length must equal the shard count.
    Endpoints(Vec<ShardEndpoint>),
}

/// Per-shard wire deadlines. A worker that cannot be reached within
/// `connect`, or does not answer a dispatch within `read`, fails that
/// batch loudly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireTimeouts {
    pub connect: Duration,
    pub read: Duration,
    /// Extra connect attempts after the first (exponential backoff).
    pub retries: u32,
    /// Backoff before retry `i` is `backoff × 2^(i−1)`.
    pub backoff: Duration,
}

impl Default for WireTimeouts {
    fn default() -> Self {
        Self {
            connect: Duration::from_secs(5),
            read: Duration::from_secs(30),
            retries: 2,
            backoff: Duration::from_millis(50),
        }
    }
}

/// Resolved per-shard endpoint (spawn mode carries the socket the child
/// will bind).
#[derive(Clone, Debug)]
pub(crate) enum Endpoint {
    Spawn { program: PathBuf, socket: PathBuf },
    Tcp(String),
    Uds(PathBuf),
}

/// Distinguishes concurrently-built sessions' spawn sockets within one
/// process.
static SOCKET_COUNTER: AtomicU64 = AtomicU64::new(0);

pub(crate) fn spawn_socket_path(shard: usize) -> PathBuf {
    let c = SOCKET_COUNTER.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "anchor-wire-{}-{}-{}.sock",
        std::process::id(),
        shard,
        c
    ))
}

enum Conn {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            Conn::Unix(s) => s.flush(),
        }
    }
}

impl Conn {
    fn set_read_timeout(&self, t: Duration) -> std::io::Result<()> {
        let t = t.max(Duration::from_millis(1)); // zero means "no timeout" to std
        match self {
            Conn::Tcp(s) => s.set_read_timeout(Some(t)),
            Conn::Unix(s) => s.set_read_timeout(Some(t)),
        }
    }
}

/// One shard worker as seen from the coordinator: connection, child
/// process (spawn mode), and the Configure payload replayed on every
/// (re)connect so a recovered worker always runs the session's exact
/// method/executor/pipeline shape.
pub struct RemoteShard {
    shard: usize,
    endpoint: Endpoint,
    timeouts: WireTimeouts,
    configure: Vec<u8>,
    conn: Option<Conn>,
    child: Option<Child>,
    seq: u64,
}

impl RemoteShard {
    pub(crate) fn new(
        shard: usize,
        endpoint: Endpoint,
        timeouts: WireTimeouts,
        configure: &ConfigureMsg,
    ) -> Self {
        Self {
            shard,
            endpoint,
            timeouts,
            configure: configure.encode(),
            conn: None,
            child: None,
            seq: 0,
        }
    }

    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Connect (or reconnect) with exponential backoff, replaying the
    /// Configure handshake. No-op while a connection is live.
    pub fn ensure_connected(&mut self) -> Result<()> {
        if self.conn.is_some() {
            return Ok(());
        }
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..=self.timeouts.retries {
            if attempt > 0 {
                std::thread::sleep(self.timeouts.backoff * 2u32.pow(attempt - 1));
            }
            match self.connect_once() {
                Ok(conn) => {
                    self.conn = Some(conn);
                    return Ok(());
                }
                Err(e) => last = Some(e),
            }
        }
        Err(anyhow!(
            "worker unreachable after {} attempt(s): {}",
            self.timeouts.retries + 1,
            last.expect("at least one attempt ran")
        ))
    }

    fn connect_once(&mut self) -> Result<Conn> {
        let deadline = Instant::now() + self.timeouts.connect;
        let mut conn = match self.endpoint.clone() {
            Endpoint::Tcp(addr) => connect_tcp(&addr, deadline)?,
            Endpoint::Uds(path) => Conn::Unix(connect_uds(&path, deadline)?),
            Endpoint::Spawn { program, socket } => {
                self.respawn_if_needed(&program, &socket)?;
                Conn::Unix(connect_uds(&socket, deadline)?)
            }
        };
        conn.set_read_timeout(self.timeouts.read)
            .map_err(|e| anyhow!("set read timeout: {e}"))?;
        // Handshake: Configure → Ready, under the read deadline.
        write_frame(&mut conn, FrameKind::Configure, &self.configure)?;
        match read_frame(&mut conn)? {
            (FrameKind::Ready, _) => Ok(conn),
            (FrameKind::Error, payload) => {
                let env = ErrorEnvelope::decode(&payload)?;
                Err(anyhow!("worker rejected configuration ({}): {}", env.status.name(), env.detail))
            }
            (kind, _) => Err(anyhow!("expected Ready, worker sent {kind:?}")),
        }
    }

    /// Spawn the child worker if it was never started or has exited.
    fn respawn_if_needed(&mut self, program: &PathBuf, socket: &PathBuf) -> Result<()> {
        if let Some(child) = self.child.as_mut() {
            match child.try_wait() {
                Ok(None) => return Ok(()), // still running
                Ok(Some(status)) => {
                    eprintln!(
                        "wire: shard {} worker exited ({status}); respawning",
                        self.shard
                    );
                }
                Err(e) => return Err(anyhow!("poll worker child: {e}")),
            }
        }
        // Remove a stale socket so the connect loop below waits for the
        // fresh child's bind instead of hitting a dead file.
        let _ = std::fs::remove_file(socket);
        let child = Command::new(program)
            .arg("worker")
            .arg("--uds")
            .arg(socket)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .spawn()
            .map_err(|e| anyhow!("spawn {}: {e}", program.display()))?;
        self.child = Some(child);
        Ok(())
    }

    /// Send one dispatch and wait for its reply. Any failure — send, read
    /// deadline, worker `Error` frame, decode, or sequence mismatch —
    /// drops the connection and returns `Err`; the *next* call reconnects.
    pub fn round_trip(&mut self, msg: &mut DispatchMsg) -> Result<ReplyMsg> {
        self.ensure_connected()?;
        self.seq += 1;
        msg.seq = self.seq;
        let payload = msg.encode();
        let result = self.round_trip_inner(&payload, msg.seq);
        if result.is_err() {
            self.conn = None;
        }
        result
    }

    fn round_trip_inner(&mut self, payload: &[u8], seq: u64) -> Result<ReplyMsg> {
        let conn = self.conn.as_mut().expect("connected");
        write_frame(conn, FrameKind::Dispatch, payload)?;
        match read_frame(conn)? {
            (FrameKind::Reply, body) => {
                let reply = ReplyMsg::decode(&body)?;
                if reply.seq != seq {
                    return Err(anyhow!(
                        "reply sequence {} does not match dispatch {seq}",
                        reply.seq
                    ));
                }
                Ok(reply)
            }
            (FrameKind::Error, body) => {
                let env = ErrorEnvelope::decode(&body)?;
                Err(anyhow!("worker error ({}): {}", env.status.name(), env.detail))
            }
            (kind, _) => Err(anyhow!("expected Reply, worker sent {kind:?}")),
        }
    }

    /// Liveness probe over the live connection.
    pub fn ping(&mut self) -> Result<()> {
        self.ensure_connected()?;
        let conn = self.conn.as_mut().expect("connected");
        write_frame(conn, FrameKind::Ping, &[])?;
        match read_frame(conn) {
            Ok((FrameKind::Pong, _)) => Ok(()),
            Ok((kind, _)) => {
                self.conn = None;
                Err(anyhow!("expected Pong, worker sent {kind:?}"))
            }
            Err(e) => {
                self.conn = None;
                Err(e)
            }
        }
    }
}

impl Drop for RemoteShard {
    fn drop(&mut self) {
        if let Some(conn) = self.conn.as_mut() {
            let _ = write_frame(conn, FrameKind::Shutdown, &[]);
        }
        if let Some(mut child) = self.child.take() {
            // The Shutdown frame above lets the worker exit cleanly; kill
            // is the backstop (a no-op if it already exited), and wait
            // reaps either way.
            let _ = child.kill();
            let _ = child.wait();
        }
        if let Endpoint::Spawn { socket, .. } = &self.endpoint {
            let _ = std::fs::remove_file(socket);
        }
    }
}

fn connect_uds(path: &std::path::Path, deadline: Instant) -> Result<UnixStream> {
    loop {
        match UnixStream::connect(path) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(anyhow!("connect to {} timed out: {e}", path.display()));
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

fn connect_tcp(addr: &str, deadline: Instant) -> Result<Conn> {
    let targets: Vec<SocketAddr> = addr
        .to_socket_addrs()
        .map_err(|e| anyhow!("resolve {addr}: {e}"))?
        .collect();
    let target = *targets.first().ok_or_else(|| anyhow!("resolve {addr}: no addresses"))?;
    loop {
        let remain = deadline.saturating_duration_since(Instant::now());
        if remain.is_zero() {
            return Err(anyhow!("connect to {addr} timed out"));
        }
        match TcpStream::connect_timeout(&target, remain) {
            Ok(s) => {
                let _ = s.set_nodelay(true);
                return Ok(Conn::Tcp(s));
            }
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(anyhow!("connect to {addr} timed out: {e}"));
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

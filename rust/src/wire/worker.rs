//! The shard worker's serve loop (`anchor-attn worker --uds <path>` /
//! `--tcp <addr>`): accept a connection, take a Configure handshake, then
//! answer Dispatch frames until the peer hangs up or sends Shutdown.
//!
//! A worker is stateless across dispatches by design: every Dispatch
//! carries the coordinator's cache seeds for the keys it routes here, the
//! worker builds a fresh `shard_worker` session around a cache seeded from
//! exactly those plans, and returns outputs plus plan coordinates. That
//! makes hit/miss/ident accounting land bit-for-bit where the in-thread
//! shard path puts it (the thread worker reads the same coordinator cache
//! the seeds were snapshotted from), and it makes worker crashes cheap:
//! there is no session state to rebuild on reconnect — the next dispatch
//! re-seeds.
//!
//! Failures inside a dispatch (bad frame, session build error, executor
//! panic) are caught and answered with a typed `Error` frame; the frame
//! stream stays aligned (frames are length-delimited), so the connection
//! survives for the next dispatch unless the transport itself broke.

use std::io::{Read, Write};
use std::net::TcpListener;
use std::os::unix::net::UnixListener;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::codec::{ConfigureMsg, DispatchMsg, ErrorEnvelope, ReplyMsg, StatusCode};
use super::frame::{read_frame_opt, write_frame, FrameKind};
use crate::attention::plan::{BatchInput, PlanCache, SparsePlan};
use crate::attention::session::AttentionSession;
use crate::util::threadpool::panic_message;

/// What ended one connection's serve loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConnFlow {
    /// Peer hung up cleanly; go back to accept.
    Eof,
    /// Peer sent Shutdown; the worker process should exit.
    Shutdown,
}

/// Serve on a Unix domain socket until a peer sends Shutdown. Removes a
/// stale socket file before binding and cleans up after itself.
pub fn serve_uds(path: &Path) -> Result<()> {
    let _ = std::fs::remove_file(path);
    let listener =
        UnixListener::bind(path).map_err(|e| anyhow!("worker: bind {}: {e}", path.display()))?;
    for stream in listener.incoming() {
        match stream {
            Ok(s) => match serve_connection(s) {
                Ok(ConnFlow::Shutdown) => break,
                Ok(ConnFlow::Eof) => {}
                Err(e) => eprintln!("worker: connection failed: {e}"),
            },
            Err(e) => eprintln!("worker: accept failed: {e}"),
        }
    }
    let _ = std::fs::remove_file(path);
    Ok(())
}

/// Serve on a TCP address until a peer sends Shutdown. Prints the bound
/// address (useful with an ephemeral `:0` port).
pub fn serve_tcp(addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).map_err(|e| anyhow!("worker: bind {addr}: {e}"))?;
    if let Ok(local) = listener.local_addr() {
        println!("worker listening on {local}");
    }
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let _ = s.set_nodelay(true);
                match serve_connection(s) {
                    Ok(ConnFlow::Shutdown) => break,
                    Ok(ConnFlow::Eof) => {}
                    Err(e) => eprintln!("worker: connection failed: {e}"),
                }
            }
            Err(e) => eprintln!("worker: accept failed: {e}"),
        }
    }
    Ok(())
}

/// Drive one connection: Configure handshake, then Dispatch/Ping until
/// EOF or Shutdown. Public within the crate so tests can serve an
/// in-process stream without spawning the binary.
pub fn serve_connection<S: Read + Write>(mut stream: S) -> Result<ConnFlow> {
    // Handshake: the first real frame must be Configure.
    let cfg = loop {
        let Some((kind, payload)) = read_frame_opt(&mut stream)? else {
            return Ok(ConnFlow::Eof);
        };
        match kind {
            FrameKind::Configure => break ConfigureMsg::decode(&payload)?,
            FrameKind::Ping => write_frame(&mut stream, FrameKind::Pong, &[])?,
            FrameKind::Shutdown => return Ok(ConnFlow::Shutdown),
            other => {
                let env = ErrorEnvelope::new(
                    StatusCode::Internal,
                    format!("expected Configure, got {other:?}"),
                );
                write_frame(&mut stream, FrameKind::Error, &env.encode())?;
                return Err(anyhow!("worker: handshake got {other:?}"));
            }
        }
    };
    write_frame(&mut stream, FrameKind::Ready, &[])?;

    loop {
        let Some((kind, payload)) = read_frame_opt(&mut stream)? else {
            return Ok(ConnFlow::Eof);
        };
        match kind {
            FrameKind::Dispatch => match run_dispatch(&cfg, &payload) {
                Ok(reply) => write_frame(&mut stream, FrameKind::Reply, &reply)?,
                Err(e) => {
                    // Frames are length-delimited, so the stream is still
                    // aligned: report the failure and keep serving.
                    let env = ErrorEnvelope::new(StatusCode::Failed, e.to_string());
                    write_frame(&mut stream, FrameKind::Error, &env.encode())?;
                }
            },
            FrameKind::Ping => write_frame(&mut stream, FrameKind::Pong, &[])?,
            FrameKind::Shutdown => return Ok(ConnFlow::Shutdown),
            other => {
                let env = ErrorEnvelope::new(
                    StatusCode::Internal,
                    format!("unexpected {other:?} frame"),
                );
                write_frame(&mut stream, FrameKind::Error, &env.encode())?;
                return Err(anyhow!("worker: unexpected {other:?} frame"));
            }
        }
    }
}

/// Decode one dispatch, run it through a fresh seeded `shard_worker`
/// session, and encode the reply. Executor panics are caught and reported
/// as errors — the same loud-failure contract as the thread path.
fn run_dispatch(cfg: &ConfigureMsg, payload: &[u8]) -> Result<Vec<u8>> {
    let msg = DispatchMsg::decode(payload)?;
    // DispatchMsg::decode proved non-empty + uniform shapes, so the
    // constructor's asserts cannot fire.
    let batch = BatchInput::new(msg.heads);
    let (n, d) = (batch.n(), batch.d());

    let cache = Arc::new(PlanCache::new());
    if cfg.cache {
        // Seed only plans matching this batch's geometry — the same filter
        // the coordinator's store seeding applies (`seed_cache_from_store`).
        let (tile, step) = cfg.method.plan_geometry();
        for (key, plan) in &msg.seeds {
            if plan.n == n
                && plan.tile == tile
                && plan.step == step
                && plan.method == cfg.method.name()
            {
                cache.seed(*key, plan.clone());
            }
        }
    }

    let mut b = AttentionSession::builder(cfg.method.clone())
        .executor(cfg.executor)
        .shard_worker();
    b = if cfg.cache { b.shared_cache(cache.clone()) } else { b.no_cache() };
    if cfg.pipelined {
        b = b.pipelined(true);
    }
    let mut session = b.build()?;
    session.set_keys(msg.keys);

    let run = catch_unwind(AssertUnwindSafe(|| session.run_batch(&batch)));
    let out = match run {
        Ok(r) => r?,
        Err(p) => return Err(anyhow!("{}", panic_message(&*p))),
    };

    // Deduplicate plans by Arc identity: a key group's shared plan crosses
    // the wire once, and the coordinator reassembles the sharing.
    let mut plans: Vec<Arc<SparsePlan>> = Vec::new();
    let mut plan_of = Vec::with_capacity(out.plans.len());
    for p in &out.plans {
        let idx = match plans.iter().position(|q| Arc::ptr_eq(q, p)) {
            Some(i) => i,
            None => {
                plans.push(p.clone());
                plans.len() - 1
            }
        };
        plan_of.push(idx as u32);
    }
    let reply = ReplyMsg {
        seq: msg.seq,
        outs: out.outputs.into_iter().map(|o| (o.out, o.cost)).collect(),
        plan_of,
        plans,
        cache_hits: out.cache_hits,
        cache_misses: out.cache_misses,
        ident_paid: out.ident_cost_paid,
        pipeline: out.pipeline,
    };
    Ok(reply.encode(d))
}

//! Arrival processes for the scenario library (DESIGN.md §16).
//!
//! The original trace generator only knew homogeneous Poisson arrivals;
//! production request streams are burstier than that. This module models
//! three processes behind one enum, each sampled deterministically from a
//! caller-owned [`Pcg64`] so traces are reproducible byte-for-byte:
//!
//! - `Poisson`: homogeneous, exponential inter-arrivals at `rate`.
//! - `OnOff`: a two-phase Markov-modulated Poisson process (MMPP). The
//!   source alternates between an ON phase emitting at `burst_rate` and a
//!   silent OFF phase; phase residence times are exponential with means
//!   `mean_on_s` / `mean_off_s`. Long-run average rate is
//!   `burst_rate · on/(on+off)`.
//! - `Ramp`: inhomogeneous Poisson whose rate climbs linearly from
//!   `start_rate` to `end_rate` over `ramp_s` seconds (then holds), sampled
//!   by thinning against `lambda_max = max(start, end)`.

use anyhow::{bail, Result};

use crate::util::rng::Pcg64;

/// A stochastic arrival process; see module docs for the taxonomy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson at `rate` req/s.
    Poisson { rate: f64 },
    /// Two-phase MMPP: ON bursts at `burst_rate` req/s, exponential phase
    /// residence with means `mean_on_s` / `mean_off_s`.
    OnOff { burst_rate: f64, mean_on_s: f64, mean_off_s: f64 },
    /// Linear rate ramp from `start_rate` to `end_rate` over `ramp_s`
    /// seconds, holding `end_rate` afterwards.
    Ramp { start_rate: f64, end_rate: f64, ramp_s: f64 },
}

impl ArrivalProcess {
    /// Validate parameters, mirroring the `shards: 0` config precedent:
    /// descriptive `Err`, no panics.
    pub fn validate(&self) -> Result<()> {
        match *self {
            ArrivalProcess::Poisson { rate } => {
                if !rate.is_finite() || rate <= 0.0 {
                    bail!("arrival rate must be > 0 (got {rate})");
                }
            }
            ArrivalProcess::OnOff { burst_rate, mean_on_s, mean_off_s } => {
                if !burst_rate.is_finite() || burst_rate <= 0.0 {
                    bail!("on/off burst_rate must be > 0 (got {burst_rate})");
                }
                if !mean_on_s.is_finite()
                    || mean_on_s <= 0.0
                    || !mean_off_s.is_finite()
                    || mean_off_s <= 0.0
                {
                    bail!(
                        "on/off phase means must be > 0 (got on {mean_on_s}, off {mean_off_s})"
                    );
                }
            }
            ArrivalProcess::Ramp { start_rate, end_rate, ramp_s } => {
                if !start_rate.is_finite()
                    || start_rate <= 0.0
                    || !end_rate.is_finite()
                    || end_rate <= 0.0
                {
                    bail!(
                        "ramp rates must be > 0 (got start {start_rate}, end {end_rate})"
                    );
                }
                if !ramp_s.is_finite() || ramp_s <= 0.0 {
                    bail!("ramp duration must be > 0 (got {ramp_s})");
                }
            }
        }
        Ok(())
    }

    /// Long-run mean rate (req/s); used for sizing sanity checks.
    pub fn mean_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::OnOff { burst_rate, mean_on_s, mean_off_s } => {
                burst_rate * mean_on_s / (mean_on_s + mean_off_s)
            }
            ArrivalProcess::Ramp { start_rate, end_rate, .. } => {
                0.5 * (start_rate + end_rate)
            }
        }
    }

    /// Sample `n` absolute arrival times (seconds from trace start) from a
    /// caller-owned RNG. Output is nondecreasing; same seed → same times.
    pub fn sample(&self, rng: &mut Pcg64, n: usize) -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        match *self {
            ArrivalProcess::Poisson { rate } => {
                let mut t = 0.0;
                for _ in 0..n {
                    t += rng.exponential(rate);
                    out.push(t);
                }
            }
            ArrivalProcess::OnOff { burst_rate, mean_on_s, mean_off_s } => {
                // Classic MMPP phase walk: inside an ON window, draw
                // candidate inter-arrivals at the burst rate; when a
                // candidate overshoots the window end, skip the OFF phase
                // and continue from the next ON window's start.
                let mut t = 0.0;
                let mut on_until = rng.exponential(1.0 / mean_on_s);
                while out.len() < n {
                    let cand = t + rng.exponential(burst_rate);
                    if cand <= on_until {
                        t = cand;
                        out.push(t);
                    } else {
                        // Jump over the OFF phase into the next ON window.
                        let off = rng.exponential(1.0 / mean_off_s);
                        t = on_until + off;
                        on_until = t + rng.exponential(1.0 / mean_on_s);
                    }
                }
            }
            ArrivalProcess::Ramp { start_rate, end_rate, ramp_s } => {
                // Thinning (Lewis–Shedler): propose at lambda_max, accept
                // with probability lambda(t)/lambda_max.
                let lambda_max = start_rate.max(end_rate);
                let mut t = 0.0;
                while out.len() < n {
                    t += rng.exponential(lambda_max);
                    let frac = (t / ramp_s).min(1.0);
                    let lambda_t = start_rate + (end_rate - start_rate) * frac;
                    if rng.next_f64() * lambda_max <= lambda_t {
                        out.push(t);
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn processes() -> Vec<ArrivalProcess> {
        vec![
            ArrivalProcess::Poisson { rate: 8.0 },
            ArrivalProcess::OnOff { burst_rate: 40.0, mean_on_s: 0.5, mean_off_s: 1.5 },
            ArrivalProcess::Ramp { start_rate: 2.0, end_rate: 20.0, ramp_s: 10.0 },
        ]
    }

    #[test]
    fn samples_are_nondecreasing_and_deterministic() {
        for p in processes() {
            let a = p.sample(&mut Pcg64::seeded(7), 500);
            let b = p.sample(&mut Pcg64::seeded(7), 500);
            assert_eq!(a, b, "{p:?} not deterministic");
            assert_eq!(a.len(), 500);
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{p:?} not ordered");
            assert!(a[0] > 0.0);
        }
    }

    #[test]
    fn poisson_rate_roughly_respected() {
        let p = ArrivalProcess::Poisson { rate: 10.0 };
        let t = p.sample(&mut Pcg64::seeded(1), 4000);
        let measured = t.len() as f64 / t.last().unwrap();
        assert!((measured - 10.0).abs() < 1.0, "measured {measured}");
    }

    #[test]
    fn onoff_long_run_rate_matches_mean() {
        let p = ArrivalProcess::OnOff { burst_rate: 40.0, mean_on_s: 0.5, mean_off_s: 1.5 };
        let t = p.sample(&mut Pcg64::seeded(2), 4000);
        let measured = t.len() as f64 / t.last().unwrap();
        let expect = p.mean_rate(); // 40 * 0.25 = 10
        assert!(
            (measured - expect).abs() < 0.25 * expect,
            "measured {measured} vs expected {expect}"
        );
    }

    #[test]
    fn onoff_is_burstier_than_poisson() {
        // Coefficient of variation of inter-arrivals: ≈1 for Poisson,
        // substantially larger for the on/off source at equal mean rate.
        let cv = |times: &[f64]| {
            let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var =
                gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
            var.sqrt() / mean
        };
        let pois = ArrivalProcess::Poisson { rate: 10.0 }.sample(&mut Pcg64::seeded(3), 4000);
        let mmpp = ArrivalProcess::OnOff { burst_rate: 40.0, mean_on_s: 0.5, mean_off_s: 1.5 }
            .sample(&mut Pcg64::seeded(3), 4000);
        assert!(cv(&mmpp) > 1.3 * cv(&pois), "mmpp cv {} pois cv {}", cv(&mmpp), cv(&pois));
    }

    #[test]
    fn ramp_accelerates() {
        let p = ArrivalProcess::Ramp { start_rate: 2.0, end_rate: 20.0, ramp_s: 50.0 };
        let t = p.sample(&mut Pcg64::seeded(4), 2000);
        // First-quarter span should be much longer than last-quarter span
        // (same request count at a higher rate).
        let q = t.len() / 4;
        let early = t[q] - t[0];
        let late = t[t.len() - 1] - t[t.len() - 1 - q];
        assert!(early > 1.5 * late, "early span {early} vs late span {late}");
    }

    #[test]
    fn validation_rejects_bad_parameters() {
        assert!(ArrivalProcess::Poisson { rate: 0.0 }.validate().is_err());
        assert!(ArrivalProcess::Poisson { rate: -1.0 }.validate().is_err());
        assert!(ArrivalProcess::OnOff { burst_rate: 5.0, mean_on_s: 0.0, mean_off_s: 1.0 }
            .validate()
            .is_err());
        assert!(ArrivalProcess::Ramp { start_rate: 1.0, end_rate: 2.0, ramp_s: 0.0 }
            .validate()
            .is_err());
        for p in processes() {
            assert!(p.validate().is_ok());
        }
    }
}

//! Synthetic workload substrate (DESIGN.md §1).
//!
//! The paper's experiments run LLaMA-3.1-8B / Qwen2.5-7B over LongBench,
//! RULER and Needle-in-a-Haystack; none are available offline, so this
//! module synthesizes Q/K/V with **exactly the score structure the paper's
//! analysis section describes** (§2.2): an attention sink at the initial
//! tokens, a dominant causal local window, sparse high-mass *stripe*
//! columns that appear and vanish across query ranges (Fig. 3b), and
//! diffuse background — with per-model profiles calibrated so the
//! anchor-region max-score dominance matches Fig. 5 (≈99 % LLaMA-like,
//! ≈90 % Qwen-like).

pub mod arrival;
pub mod qkv;
pub mod scenario;
pub mod trace;

pub use qkv::{HeadKind, Workload, WorkloadMeta, WorkloadProfile};

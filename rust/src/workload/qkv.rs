//! Structured Q/K/V synthesis.
//!
//! Head-dim channels are partitioned into orthogonal feature subspaces so
//! every score component is independently controllable (all target levels
//! are *scaled* logits, i.e. after the 1/√d of attention):
//!
//! | subspace    | dims      | produces                                   |
//! |-------------|-----------|--------------------------------------------|
//! | sink        | 1         | high scores on the first `sink_tokens` keys|
//! | positional  | 2·freqs   | local-window peak decaying with distance   |
//! | topic       | 16        | stripe columns active on query sub-ranges  |
//! | noise       | remainder | diffuse background scores                  |
//!
//! The positional subspace uses random Fourier features: matched
//! cos/sin pairs give `Σ c² cos(ω_l (i−j))`, a Gaussian-like bump around
//! the diagonal whose width is `local_decay_tokens`.

use crate::attention::HeadInput;
use crate::tensor::Mat;
use crate::util::rng::Pcg64;

/// Head archetypes for multi-head grids (Fig. 4's per-head diversity).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadKind {
    /// Strong local window, few stripes — most heads.
    LocalHeavy,
    /// Many strong stripes (retrieval heads).
    Retrieval,
    /// Sink dominates everything.
    SinkHeavy,
    /// Weak structure, high noise — the hard case for sparsity.
    Diffuse,
}

impl HeadKind {
    pub fn all() -> [HeadKind; 4] {
        [HeadKind::LocalHeavy, HeadKind::Retrieval, HeadKind::SinkHeavy, HeadKind::Diffuse]
    }

    /// Deterministic kind for a (layer, head) cell of an evaluation grid,
    /// biased toward LocalHeavy like real models.
    pub fn for_cell(layer: usize, head: usize) -> HeadKind {
        match (layer * 7 + head * 3) % 8 {
            0 | 1 | 2 | 3 => HeadKind::LocalHeavy,
            4 | 5 => HeadKind::Retrieval,
            6 => HeadKind::SinkHeavy,
            _ => HeadKind::Diffuse,
        }
    }
}

/// Generation profile. All `*_logit` fields are scaled-logit targets.
#[derive(Clone, Debug)]
pub struct WorkloadProfile {
    pub d: usize,
    pub sink_tokens: usize,
    pub sink_logit: f32,
    pub local_peak_logit: f32,
    pub local_decay_tokens: f32,
    pub local_freqs: usize,
    /// Stripes per 4k tokens (scaled with N).
    pub stripes_per_4k: f32,
    pub stripe_logit_lo: f32,
    pub stripe_logit_hi: f32,
    pub noise_logit_std: f32,
    /// Scaled-logit std of *block-shared* query noise. Per-row noise
    /// averages away under `avgpool(Q, b_q)` (the identification input),
    /// so this term controls how smoothly the θ sweep trades sparsity for
    /// recall at pooled granularity (paper Table 4's regime).
    pub block_noise_logit_std: f32,
    /// Rows sharing one block-noise vector (match the engine's b_q).
    pub block_rows: usize,
}

impl WorkloadProfile {
    /// LLaMA-3.1-like: anchor regions dominate ≈99 % of row maxima
    /// (paper Fig. 5 left).
    pub fn llama_like() -> Self {
        Self {
            d: 64,
            sink_tokens: 4,
            sink_logit: 12.0,
            local_peak_logit: 16.0,
            local_decay_tokens: 96.0,
            local_freqs: 8,
            stripes_per_4k: 12.0,
            stripe_logit_lo: 5.0,
            stripe_logit_hi: 13.0,
            noise_logit_std: 1.2,
            block_noise_logit_std: 2.0,
            block_rows: 128,
        }
    }

    /// Qwen2.5-like: stripes frequently beat the anchor regions, dominance
    /// ≈90 % (paper Fig. 5 right).
    pub fn qwen_like() -> Self {
        Self {
            d: 64,
            sink_tokens: 4,
            sink_logit: 10.0,
            local_peak_logit: 13.0,
            local_decay_tokens: 64.0,
            local_freqs: 8,
            stripes_per_4k: 18.0,
            stripe_logit_lo: 7.0,
            stripe_logit_hi: 15.0,
            noise_logit_std: 1.8,
            block_noise_logit_std: 2.5,
            block_rows: 128,
        }
    }

    /// Adjust the profile for a head archetype.
    pub fn with_kind(mut self, kind: HeadKind) -> Self {
        match kind {
            HeadKind::LocalHeavy => {}
            HeadKind::Retrieval => {
                self.stripes_per_4k *= 2.5;
                self.stripe_logit_hi += 1.5;
                self.local_peak_logit -= 1.0;
            }
            HeadKind::SinkHeavy => {
                self.sink_logit += 3.0;
                self.stripes_per_4k *= 0.5;
            }
            HeadKind::Diffuse => {
                self.noise_logit_std *= 2.0;
                self.local_peak_logit -= 2.0;
                self.stripe_logit_lo -= 2.0;
                self.stripe_logit_hi -= 2.0;
            }
        }
        self
    }
}

/// A planted needle (RULER / NIAH proxies): one key at a known depth whose
/// score for *every* query beats the background, with a recognizable value
/// signature to verify retrieval in the output.
#[derive(Clone, Debug)]
pub struct NeedleSpec {
    pub position: usize,
    pub logit: f32,
    /// The value-row signature planted at `position`.
    pub signature: Vec<f32>,
}

/// A stripe column: key `col` is hot for query rows `[row_start, row_end)`
/// (Fig. 3b's appearing/vanishing stripes).
#[derive(Clone, Copy, Debug)]
pub struct StripeSpec {
    pub col: u32,
    pub row_start: u32,
    pub row_end: u32,
    pub logit: f32,
}

/// Ground-truth generation metadata, used by the experiment harness.
#[derive(Clone, Debug, Default)]
pub struct WorkloadMeta {
    pub sink_tokens: usize,
    pub stripes: Vec<StripeSpec>,
    pub needle: Option<NeedleSpec>,
}

/// A generated head plus its ground truth.
#[derive(Clone, Debug)]
pub struct Workload {
    pub head: HeadInput,
    pub meta: WorkloadMeta,
}

const TOPIC_DIMS: usize = 16;

/// Generate one head of length `n`. Deterministic in `(profile, n, seed)`.
pub fn generate(profile: &WorkloadProfile, n: usize, seed: u64) -> Workload {
    generate_with_needle(profile, n, seed, None)
}

/// Generate with an optional needle planted at `depth_frac ∈ [0,1)`.
pub fn generate_with_needle(
    profile: &WorkloadProfile,
    n: usize,
    seed: u64,
    needle_depth_frac: Option<f64>,
) -> Workload {
    let d = profile.d;
    let pos_dims = 2 * profile.local_freqs;
    assert!(
        d >= 1 + pos_dims + TOPIC_DIMS + 8,
        "head dim {d} too small for channel layout"
    );
    let noise_dims = d - 1 - pos_dims - TOPIC_DIMS;
    let sqrt_d = (d as f32).sqrt();

    let mut rng = Pcg64::seeded(seed);
    let mut q = Mat::zeros(n, d);
    let mut k = Mat::zeros(n, d);
    let mut v = Mat::from_fn(n, d, |_, _| rng.normal());

    // --- sink channel (dim 0) ------------------------------------------
    let s_amp = (profile.sink_logit * sqrt_d).sqrt();
    for i in 0..n {
        q.set(i, 0, s_amp * (1.0 + 0.05 * rng.normal()));
    }
    for (j, row) in (0..n).zip(0..n) {
        let _ = row;
        let val = if j < profile.sink_tokens {
            s_amp * (1.0 + 0.05 * rng.normal())
        } else {
            0.1 * rng.normal()
        };
        k.set(j, 0, val);
    }

    // --- positional channels (dims 1 .. 1+pos_dims) ---------------------
    // Σ_l c² cos(ω_l (i-j)); peak Σ c²·L = local_peak·√d.
    let c_amp = (profile.local_peak_logit * sqrt_d / profile.local_freqs as f32).sqrt();
    let omegas: Vec<f32> = (0..profile.local_freqs)
        .map(|_| (rng.normal() * 2.0 / profile.local_decay_tokens).abs() + 1e-4)
        .collect();
    let phases: Vec<f32> = (0..profile.local_freqs)
        .map(|_| rng.uniform(0.0, std::f32::consts::TAU))
        .collect();
    for i in 0..n {
        for (l, (&w, &ph)) in omegas.iter().zip(&phases).enumerate() {
            let ang = w * i as f32 + ph;
            q.set(i, 1 + 2 * l, c_amp * ang.cos());
            q.set(i, 2 + 2 * l, c_amp * ang.sin());
            k.set(i, 1 + 2 * l, c_amp * ang.cos());
            k.set(i, 2 + 2 * l, c_amp * ang.sin());
        }
    }

    // --- topic subspace: stripes ----------------------------------------
    let topic0 = 1 + pos_dims;
    // Per-row cap on the topic-subspace norm: rows subscribing to several
    // stripes would otherwise compound cross-terms past the local peak
    // (observed dominance collapse); a query realistically commits to one
    // dominant topic, so the combined component is renormalized to the
    // largest subscribed amplitude.
    let mut max_amp = vec![0.0f32; n];
    let n_stripes = ((n as f32 / 4096.0) * profile.stripes_per_4k).round().max(1.0) as usize;
    let mut stripes = Vec::with_capacity(n_stripes);
    for _ in 0..n_stripes {
        // Stripe key position: outside the sink, anywhere in context.
        let col = profile.sink_tokens
            + rng.next_below((n - profile.sink_tokens) as u64) as usize;
        // Active query range: starts after the key (causality), random
        // length; ~30% run to the end, others vanish (Fig. 3b).
        let row_start =
            col + 1 + (rng.next_below(((n - col) as u64).max(1)) / 2) as usize;
        let row_start = row_start.min(n - 1);
        let remaining = n - row_start;
        let row_end = if rng.next_f32() < 0.3 {
            n
        } else {
            row_start + 1 + rng.next_below(remaining as u64) as usize
        };
        let logit = rng.uniform(profile.stripe_logit_lo, profile.stripe_logit_hi);
        // Random unit direction in the topic subspace.
        let mut dir = [0.0f32; TOPIC_DIMS];
        let mut norm = 0.0;
        for x in dir.iter_mut() {
            *x = rng.normal();
            norm += *x * *x;
        }
        let norm = norm.sqrt().max(1e-6);
        let amp = (logit * sqrt_d).sqrt();
        for (t, &x) in dir.iter().enumerate() {
            let u = x / norm * amp;
            k.set(col, topic0 + t, k.at(col, topic0 + t) + u);
            for r in row_start..row_end {
                q.set(r, topic0 + t, q.at(r, topic0 + t) + u);
            }
        }
        for r in row_start..row_end {
            max_amp[r] = max_amp[r].max(amp);
        }
        stripes.push(StripeSpec {
            col: col as u32,
            row_start: row_start as u32,
            row_end: row_end as u32,
            logit,
        });
    }

    // Renormalize each row's topic component to its largest single
    // subscription amplitude (see max_amp comment above).
    for r in 0..n {
        if max_amp[r] == 0.0 {
            continue;
        }
        let mut norm2 = 0.0f32;
        for t in 0..TOPIC_DIMS {
            let x = q.at(r, topic0 + t);
            norm2 += x * x;
        }
        let norm = norm2.sqrt();
        if norm > max_amp[r] {
            let scale = max_amp[r] / norm;
            for t in 0..TOPIC_DIMS {
                q.set(r, topic0 + t, q.at(r, topic0 + t) * scale);
            }
        }
    }

    // --- needle -----------------------------------------------------------
    let needle = needle_depth_frac.map(|frac| {
        let position =
            (profile.sink_tokens + ((n - profile.sink_tokens - 1) as f64 * frac) as usize)
                .min(n - 1);
        // Needle logit: comfortably above background, at stripe-hi level.
        let logit = profile.stripe_logit_hi + 1.0;
        let amp = (logit * sqrt_d).sqrt();
        let mut dir = [0.0f32; TOPIC_DIMS];
        let mut norm = 0.0;
        for x in dir.iter_mut() {
            *x = rng.normal();
            norm += *x * *x;
        }
        let norm = norm.sqrt().max(1e-6);
        for (t, &x) in dir.iter().enumerate() {
            let u = x / norm * amp;
            k.set(position, topic0 + t, k.at(position, topic0 + t) + u);
            // Every query carries the probe (the "question" is global).
            for r in 0..n {
                q.set(r, topic0 + t, q.at(r, topic0 + t) + u);
            }
        }
        // Distinctive value signature so retrieval is visible in outputs.
        let signature: Vec<f32> = (0..d).map(|_| 3.0 * rng.normal()).collect();
        for (c, &s) in signature.iter().enumerate() {
            v.set(position, c, s);
        }
        NeedleSpec { position, logit, signature }
    });

    // --- noise subspace ---------------------------------------------------
    // dot std over R dims with iid N(0,σ): σ²·√R = noise_std·√d.
    if noise_dims > 0 {
        let sigma = (profile.noise_logit_std * sqrt_d / (noise_dims as f32).sqrt()).sqrt();
        let base = d - noise_dims;
        for i in 0..n {
            for c in base..d {
                q.set(i, c, sigma * rng.normal());
                k.set(i, c, sigma * rng.normal());
            }
        }
        // Block-shared query noise: survives avgpool(Q, block_rows), so
        // pooled background scores have std ≈ block_noise_logit_std.
        if profile.block_noise_logit_std > 0.0 {
            let sigma_b =
                profile.block_noise_logit_std * sqrt_d / (sigma * (noise_dims as f32).sqrt());
            let blocks = n.div_ceil(profile.block_rows);
            for b in 0..blocks {
                let bias: Vec<f32> = (0..noise_dims).map(|_| sigma_b * rng.normal()).collect();
                let start = b * profile.block_rows;
                let end = (start + profile.block_rows).min(n);
                for i in start..end {
                    for (ci, &bv) in bias.iter().enumerate() {
                        let c = base + ci;
                        q.set(i, c, q.at(i, c) + bv);
                    }
                }
            }
        }
    }

    Workload {
        head: HeadInput::new(q, k, v),
        meta: WorkloadMeta { sink_tokens: profile.sink_tokens, stripes, needle },
    }
}

/// Fraction of query rows whose maximum scaled logit lies in the anchor
/// regions (initial `init_tokens` ∪ trailing `window` tokens) — the Fig. 5
/// metric (paper: first token + 128-token window).
pub fn anchor_dominance_init(head: &HeadInput, init_tokens: usize, window: usize) -> f64 {
    let n = head.n();
    let scale = head.scale();
    let mut hits = 0usize;
    let rows = crate::util::threadpool::parallel_map(n, |r| {
        let qrow = head.q.row(r);
        let mut best = f32::NEG_INFINITY;
        let mut best_j = 0usize;
        for j in 0..=r {
            let s = crate::tensor::dot(qrow, head.k.row(j), head.q.cols) * scale;
            if s > best {
                best = s;
                best_j = j;
            }
        }
        let win_start = r.saturating_sub(window.saturating_sub(1));
        best_j < init_tokens || best_j >= win_start
    });
    for h in rows {
        if h {
            hits += 1;
        }
    }
    hits as f64 / n as f64
}

/// Paper-strict variant: only the very first token counts as initial.
pub fn anchor_dominance(head: &HeadInput, window: usize) -> f64 {
    anchor_dominance_init(head, 1, window)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let p = WorkloadProfile::llama_like();
        let a = generate(&p, 512, 42);
        let b = generate(&p, 512, 42);
        assert_eq!(a.head.q.data, b.head.q.data);
        assert_eq!(a.head.k.data, b.head.k.data);
        assert_eq!(a.meta.stripes.len(), b.meta.stripes.len());
    }

    #[test]
    fn different_seeds_differ() {
        let p = WorkloadProfile::llama_like();
        let a = generate(&p, 256, 1);
        let b = generate(&p, 256, 2);
        assert_ne!(a.head.q.data, b.head.q.data);
    }

    #[test]
    fn sink_scores_are_high() {
        let p = WorkloadProfile::llama_like();
        let w = generate(&p, 512, 7);
        let h = &w.head;
        let scale = h.scale();
        // Mean scaled logit from mid queries to key 0 should be in the
        // sink_logit regime (positional features add an oscillatory
        // residual of up to ±local_peak/2), and must dominate a background
        // key by a wide margin.
        let mut sink = 0.0;
        let mut bg = 0.0;
        for r in 256..512 {
            sink += crate::tensor::dot(h.q.row(r), h.k.row(0), h.d()) * scale;
            bg += crate::tensor::dot(h.q.row(r), h.k.row(137), h.d()) * scale;
        }
        sink /= 256.0;
        bg /= 256.0;
        // Positional residual scales with local_peak; only require the
        // sink channel to land in its regime and to dominate background.
        assert!((sink - p.sink_logit).abs() < p.local_peak_logit * 0.75, "mean sink logit {sink}");
        assert!(sink > bg + 4.0, "sink {sink} vs background {bg}");
    }

    #[test]
    fn local_peak_near_diagonal() {
        let p = WorkloadProfile::llama_like();
        let w = generate(&p, 512, 8);
        let h = &w.head;
        let scale = h.scale();
        // Self-score (diagonal) should be near sink+local_peak+stripe terms;
        // at least it must dominate a far-away background key.
        let mut diag = 0.0;
        let mut far = 0.0;
        let mut cnt = 0.0;
        for r in (300..500).step_by(10) {
            diag += crate::tensor::dot(h.q.row(r), h.k.row(r), h.d()) * scale;
            far += crate::tensor::dot(h.q.row(r), h.k.row(100), h.d()) * scale;
            cnt += 1.0;
        }
        assert!(diag / cnt > far / cnt + 4.0, "diag {} far {}", diag / cnt, far / cnt);
    }

    #[test]
    fn stripe_rows_see_stripe_key() {
        let p = WorkloadProfile::llama_like();
        let w = generate(&p, 1024, 9);
        let h = &w.head;
        let scale = h.scale();
        for s in &w.meta.stripes {
            if s.row_end - s.row_start < 4 || s.logit < 6.0 {
                continue;
            }
            let r = (s.row_start as usize + s.row_end as usize) / 2;
            let hot = crate::tensor::dot(h.q.row(r), h.k.row(s.col as usize), h.d()) * scale;
            // Compare to a background key at similar distance.
            assert!(
                hot > s.logit - 4.0,
                "stripe col {} logit {} observed {hot}",
                s.col,
                s.logit
            );
        }
    }

    #[test]
    fn llama_dominance_exceeds_qwen() {
        let n = 4096;
        let wl = generate(&WorkloadProfile::llama_like(), n, 10);
        let wq = generate(&WorkloadProfile::qwen_like(), n, 10);
        let dl = anchor_dominance_init(&wl.head, 4, 128);
        let dq = anchor_dominance_init(&wq.head, 4, 128);
        assert!(dl > dq, "llama {dl} vs qwen {dq}");
        assert!(dl > 0.93, "llama-like dominance {dl}");
        assert!(dq < 0.99, "qwen-like dominance {dq}");
        assert!(dq > 0.55, "qwen-like dominance {dq} too low");
    }

    #[test]
    fn needle_is_plantable_and_hot() {
        let p = WorkloadProfile::llama_like();
        let w = generate_with_needle(&p, 1024, 11, Some(0.5));
        let needle = w.meta.needle.as_ref().unwrap();
        assert!(needle.position > 400 && needle.position < 620);
        let h = &w.head;
        let scale = h.scale();
        // Late queries see the needle strongly.
        let s = crate::tensor::dot(h.q.row(1000), h.k.row(needle.position), h.d()) * scale;
        assert!(s > needle.logit - 4.0, "needle score {s}");
        // Value row carries the signature.
        for (c, &sig) in needle.signature.iter().enumerate() {
            assert_eq!(h.v.at(needle.position, c), sig);
        }
    }

    #[test]
    fn head_kinds_modify_profile() {
        let base = WorkloadProfile::llama_like();
        let retr = base.clone().with_kind(HeadKind::Retrieval);
        assert!(retr.stripes_per_4k > base.stripes_per_4k);
        let diff = base.clone().with_kind(HeadKind::Diffuse);
        assert!(diff.noise_logit_std > base.noise_logit_std);
        // Deterministic kind grid.
        assert_eq!(HeadKind::for_cell(0, 0), HeadKind::for_cell(0, 0));
    }
}

/// Diagnostic: classify where each row's max logit lands.
/// Returns (init, window, stripe_col, other) fractions.
pub fn dominance_breakdown(
    wl: &Workload,
    init_tokens: usize,
    window: usize,
) -> (f64, f64, f64, f64) {
    let head = &wl.head;
    let n = head.n();
    let scale = head.scale();
    let stripe_cols: std::collections::HashSet<u32> =
        wl.meta.stripes.iter().map(|s| s.col).collect();
    let classes = crate::util::threadpool::parallel_map(n, |r| {
        let qrow = head.q.row(r);
        let mut best = f32::NEG_INFINITY;
        let mut best_j = 0usize;
        for j in 0..=r {
            let s = crate::tensor::dot(qrow, head.k.row(j), head.q.cols) * scale;
            if s > best {
                best = s;
                best_j = j;
            }
        }
        let win_start = r.saturating_sub(window.saturating_sub(1));
        if best_j < init_tokens {
            0u8
        } else if best_j >= win_start {
            1
        } else if stripe_cols.contains(&(best_j as u32)) {
            2
        } else {
            3
        }
    });
    let count = |c: u8| classes.iter().filter(|&&x| x == c).count() as f64 / n as f64;
    (count(0), count(1), count(2), count(3))
}

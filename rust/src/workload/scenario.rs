//! Scenario library: multi-tenant serving traces (DESIGN.md §16).
//!
//! Grows the single Poisson generator in [`super::trace`] into a model of
//! production traffic. A trace is composed from **tenants**; each tenant
//! has a request shape ([`ScenarioKind`]), an arrival process
//! ([`ArrivalProcess`]), and prompt/decode length distributions
//! ([`LengthDist`], including the heavy-tailed log-normal and bounded
//! Pareto families). Tenants sample from independent [`Pcg64`] streams and
//! their request streams are merged by arrival time, so adding a tenant
//! never perturbs another tenant's draws.
//!
//! Every request carries a `reuse_key` describing its plan-cache identity:
//! shared-prefix requests in the same prefix group share a key (their
//! prefixes are literally identical), RAG requests share keys through a
//! small document corpus, long-doc requests share per length bucket, and
//! needle requests are unique by construction. The serving harness maps
//! `(scenario, reuse_key)` onto `PlanKey`s, which is what makes plan-cache
//! and store-seed hits *attributable to a scenario* in `BENCH_serve.json`.

use anyhow::{bail, Context, Result};

use super::arrival::ArrivalProcess;
use crate::util::rng::Pcg64;

/// Request shape taxonomy (DESIGN.md §16).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ScenarioKind {
    /// One long document per request; moderate cross-request commonality
    /// (plans generalize within a length bucket).
    LongDoc,
    /// Retrieval-augmented: many short chunks drawn from a shared corpus;
    /// high plan reuse through repeated documents.
    Rag,
    /// Multi-turn with a shared conversation prefix: requests in a prefix
    /// group carry byte-identical prefixes, the best case for the plan
    /// cache and store seeding.
    SharedPrefix,
    /// Needle-in-a-haystack probes: every context unique, worst case for
    /// reuse (the control scenario the CI gate compares against).
    Needle,
}

impl ScenarioKind {
    /// Stable tag used in reports and per-scenario breakdowns.
    pub fn tag(&self) -> &'static str {
        match self {
            ScenarioKind::LongDoc => "long-doc",
            ScenarioKind::Rag => "rag",
            ScenarioKind::SharedPrefix => "shared-prefix",
            ScenarioKind::Needle => "needle",
        }
    }

    /// Stable numeric id (the harness uses it as the `PlanKey` layer).
    pub fn index(&self) -> u32 {
        match self {
            ScenarioKind::LongDoc => 0,
            ScenarioKind::Rag => 1,
            ScenarioKind::SharedPrefix => 2,
            ScenarioKind::Needle => 3,
        }
    }
}

/// Prompt/decode length distributions. All samples are clamped to the
/// distribution's own `[min, max]`, so a tenant can never emit a request
/// larger than its configured envelope.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LengthDist {
    Fixed { tokens: usize },
    Uniform { min: usize, max: usize },
    /// Log-normal around `median`: `median · exp(sigma · N(0,1))`, clamped.
    LogNormal { median: usize, sigma: f64, min: usize, max: usize },
    /// Bounded Pareto on `[min, max]` with tail index `alpha` (smaller
    /// alpha → heavier tail), via the inverse CDF.
    BoundedPareto { alpha: f64, min: usize, max: usize },
}

impl LengthDist {
    pub fn validate(&self) -> Result<()> {
        match *self {
            LengthDist::Fixed { tokens } => {
                if tokens == 0 {
                    bail!("fixed length must be > 0");
                }
            }
            LengthDist::Uniform { min, max } => {
                if min == 0 || min > max {
                    bail!("uniform length bounds invalid: [{min}, {max}]");
                }
            }
            LengthDist::LogNormal { median, sigma, min, max } => {
                if median == 0 || min == 0 || min > max {
                    bail!("log-normal length bounds invalid: median {median}, [{min}, {max}]");
                }
                if !sigma.is_finite() || sigma <= 0.0 {
                    bail!("log-normal sigma must be > 0 (got {sigma})");
                }
            }
            LengthDist::BoundedPareto { alpha, min, max } => {
                if min == 0 || min >= max {
                    bail!("bounded-Pareto bounds invalid: [{min}, {max}]");
                }
                if !alpha.is_finite() || alpha <= 0.0 {
                    bail!("bounded-Pareto alpha must be > 0 (got {alpha})");
                }
            }
        }
        Ok(())
    }

    /// Largest value this distribution can emit.
    pub fn max_tokens(&self) -> usize {
        match *self {
            LengthDist::Fixed { tokens } => tokens,
            LengthDist::Uniform { max, .. }
            | LengthDist::LogNormal { max, .. }
            | LengthDist::BoundedPareto { max, .. } => max,
        }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        match *self {
            LengthDist::Fixed { tokens } => tokens,
            LengthDist::Uniform { min, max } => {
                min + rng.next_below((max - min + 1) as u64) as usize
            }
            LengthDist::LogNormal { median, sigma, min, max } => {
                let x = median as f64 * (sigma * rng.normal() as f64).exp();
                (x.round() as usize).clamp(min, max)
            }
            LengthDist::BoundedPareto { alpha, min, max } => {
                // Inverse CDF: x = L · (1 - U·(1 - (L/H)^a))^(-1/a).
                let (l, h) = (min as f64, max as f64);
                let u = rng.next_f64();
                let x = l * (1.0 - u * (1.0 - (l / h).powf(alpha))).powf(-1.0 / alpha);
                (x.round() as usize).clamp(min, max)
            }
        }
    }
}

/// One traffic source in a scenario mix.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    /// Report label, e.g. `"rag-burst"`.
    pub name: String,
    pub kind: ScenarioKind,
    pub arrival: ArrivalProcess,
    /// Total prompt length (for shared-prefix: prefix + suffix envelope).
    pub prompt: LengthDist,
    pub decode: LengthDist,
    pub requests: usize,
    /// Shared-prefix only: number of conversation groups. Each group draws
    /// one prefix length from `prompt` and every request in the group
    /// reuses it verbatim.
    pub prefix_groups: usize,
    /// Shared-prefix only: fresh suffix tokens appended per turn.
    pub suffix: LengthDist,
    /// RAG only: corpus size; reuse keys cycle through this many documents.
    pub rag_corpus: usize,
}

impl TenantSpec {
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("tenant name must be non-empty");
        }
        if self.requests == 0 {
            bail!("tenant {} must have requests > 0", self.name);
        }
        self.arrival.validate().with_context(|| format!("tenant {}", self.name))?;
        self.prompt.validate().with_context(|| format!("tenant {} prompt", self.name))?;
        self.decode.validate().with_context(|| format!("tenant {} decode", self.name))?;
        if self.kind == ScenarioKind::SharedPrefix {
            if self.prefix_groups == 0 {
                bail!("tenant {}: shared-prefix needs prefix_groups > 0", self.name);
            }
            self.suffix
                .validate()
                .with_context(|| format!("tenant {} suffix", self.name))?;
        }
        Ok(())
    }
}

/// A full scenario: tenant mix plus seed.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioConfig {
    pub seed: u64,
    pub tenants: Vec<TenantSpec>,
}

/// One generated request. Superset of [`super::trace::TraceRequest`] with
/// attribution metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioRequest {
    /// Global id in merged arrival order.
    pub id: u64,
    /// Arrival time in seconds from trace start (nondecreasing).
    pub arrival_s: f64,
    pub prompt_tokens: usize,
    pub decode_tokens: usize,
    pub kind: ScenarioKind,
    /// Index into `ScenarioConfig::tenants`.
    pub tenant: u32,
    /// Shared-prefix: conversation group id within the tenant.
    pub prefix_group: Option<u32>,
    /// Shared-prefix: length of the byte-identical shared prefix
    /// (identical for every request in a group); 0 otherwise.
    pub prefix_tokens: usize,
    /// Plan-cache identity: requests with equal `(kind, reuse_key)` should
    /// hit each other's cached plans. Needle keys are globally unique.
    pub reuse_key: u64,
}

impl ScenarioConfig {
    pub fn validate(&self) -> Result<()> {
        if self.tenants.is_empty() {
            bail!("scenario needs at least one tenant");
        }
        for t in &self.tenants {
            t.validate()?;
        }
        Ok(())
    }

    /// Largest prompt any tenant can emit (for `max_seq` sizing).
    pub fn max_prompt_tokens(&self) -> usize {
        self.tenants
            .iter()
            .map(|t| {
                if t.kind == ScenarioKind::SharedPrefix {
                    t.prompt.max_tokens() + t.suffix.max_tokens()
                } else {
                    t.prompt.max_tokens()
                }
            })
            .max()
            .unwrap_or(0)
    }

    pub fn total_requests(&self) -> usize {
        self.tenants.iter().map(|t| t.requests).sum()
    }

    /// Generate the merged trace. Deterministic: each tenant draws from
    /// `Pcg64::new(seed, tenant_index + 1)`, streams are merged by
    /// `(arrival_s, tenant)`, and ids follow merged order.
    pub fn generate(&self) -> Result<Vec<ScenarioRequest>> {
        self.validate()?;
        let mut all: Vec<ScenarioRequest> = Vec::with_capacity(self.total_requests());
        let mut needle_counter: u64 = 0;
        for (ti, tenant) in self.tenants.iter().enumerate() {
            let mut rng = Pcg64::new(self.seed, ti as u64 + 1);
            let arrivals = tenant.arrival.sample(&mut rng, tenant.requests);
            // Shared-prefix: pre-draw one prefix length per group so every
            // request in the group reuses it verbatim.
            let group_prefixes: Vec<usize> = if tenant.kind == ScenarioKind::SharedPrefix {
                (0..tenant.prefix_groups).map(|_| tenant.prompt.sample(&mut rng)).collect()
            } else {
                Vec::new()
            };
            for (ri, &arrival_s) in arrivals.iter().enumerate() {
                let (prompt_tokens, prefix_tokens, prefix_group, reuse_key) = match tenant.kind
                {
                    ScenarioKind::SharedPrefix => {
                        let g = rng.next_below(tenant.prefix_groups as u64) as u32;
                        let prefix = group_prefixes[g as usize];
                        let suffix = tenant.suffix.sample(&mut rng);
                        // Stable per (tenant, group): every turn of a
                        // conversation maps to the same plan identity.
                        let key = (ti as u64) << 32 | g as u64;
                        (prefix + suffix, prefix, Some(g), key)
                    }
                    ScenarioKind::Needle => {
                        let len = tenant.prompt.sample(&mut rng);
                        needle_counter += 1;
                        // Unique per request: needle probes never share
                        // plans (the reuse control group).
                        (len, 0, None, u64::MAX - needle_counter)
                    }
                    ScenarioKind::Rag => {
                        let len = tenant.prompt.sample(&mut rng);
                        let doc = rng.next_below(tenant.rag_corpus.max(1) as u64);
                        (len, 0, None, (ti as u64) << 32 | doc)
                    }
                    ScenarioKind::LongDoc => {
                        let len = tenant.prompt.sample(&mut rng);
                        // Bucket by log2 length: plans generalize within a
                        // bucket, not across an order of magnitude.
                        let bucket = (len.max(1) as f64).log2().floor() as u64;
                        (len, 0, None, (ti as u64) << 32 | bucket)
                    }
                };
                let decode_tokens = tenant.decode.sample(&mut rng).max(1);
                all.push(ScenarioRequest {
                    id: ri as u64, // provisional; rewritten after the merge
                    arrival_s,
                    prompt_tokens: prompt_tokens.max(16),
                    decode_tokens,
                    kind: tenant.kind,
                    tenant: ti as u32,
                    prefix_group,
                    prefix_tokens,
                    reuse_key,
                });
            }
        }
        // Merge tenant streams by arrival time (tenant index breaks ties
        // deterministically), then assign global ids in arrival order.
        all.sort_by(|a, b| {
            a.arrival_s
                .partial_cmp(&b.arrival_s)
                .expect("arrival times are finite")
                .then(a.tenant.cmp(&b.tenant))
        });
        for (i, r) in all.iter_mut().enumerate() {
            r.id = i as u64;
        }
        Ok(all)
    }
}

/// FNV-1a digest over the deterministic fields of a request stream. Two
/// runs of the same scenario+seed must produce equal digests; the harness
/// embeds it in `bench_serve.json` and CI double-runs to compare.
pub fn stream_digest(reqs: &[ScenarioRequest]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    for r in reqs {
        eat(r.id);
        eat(r.arrival_s.to_bits());
        eat(r.prompt_tokens as u64);
        eat(r.decode_tokens as u64);
        eat(r.kind.index() as u64);
        eat(r.tenant as u64);
        eat(r.prefix_tokens as u64);
        eat(r.reuse_key);
    }
    h
}

/// Named scenario mixes behind `bench serve --scenario <name>`. Lengths
/// are sized for the default `max_seq = 2048` serving envelope.
pub fn named_scenario(name: &str, requests: usize, seed: u64) -> Result<ScenarioConfig> {
    let requests = requests.max(4);
    let tenants = match name {
        "long-doc" => vec![long_doc_tenant(requests, 6.0)],
        "rag" => vec![rag_tenant(requests)],
        "shared-prefix" => vec![shared_prefix_tenant(requests, 8.0)],
        "needle" => vec![needle_tenant(requests)],
        "mixed" => {
            // Four tenants with distinct shapes *and* distinct arrival
            // processes; uneven split keeps the mix heavy on the reuse
            // scenarios the gate compares.
            let q = requests / 4;
            vec![
                long_doc_tenant(q, 4.0),
                rag_tenant(q),
                shared_prefix_tenant(requests - 3 * q, 10.0),
                needle_tenant(q),
            ]
        }
        other => bail!(
            "unknown scenario {other:?} (expected long-doc | rag | shared-prefix | needle | mixed)"
        ),
    };
    let cfg = ScenarioConfig { seed, tenants };
    cfg.validate()?;
    Ok(cfg)
}

fn long_doc_tenant(requests: usize, rate: f64) -> TenantSpec {
    TenantSpec {
        name: "long-doc".into(),
        kind: ScenarioKind::LongDoc,
        arrival: ArrivalProcess::Poisson { rate },
        prompt: LengthDist::LogNormal { median: 768, sigma: 0.45, min: 256, max: 1536 },
        decode: LengthDist::Uniform { min: 4, max: 16 },
        requests,
        prefix_groups: 0,
        suffix: LengthDist::Fixed { tokens: 1 },
        rag_corpus: 0,
    }
}

fn rag_tenant(requests: usize) -> TenantSpec {
    TenantSpec {
        name: "rag-burst".into(),
        kind: ScenarioKind::Rag,
        arrival: ArrivalProcess::OnOff { burst_rate: 40.0, mean_on_s: 0.4, mean_off_s: 1.2 },
        prompt: LengthDist::BoundedPareto { alpha: 1.3, min: 128, max: 1024 },
        decode: LengthDist::Uniform { min: 4, max: 24 },
        requests,
        prefix_groups: 0,
        suffix: LengthDist::Fixed { tokens: 1 },
        rag_corpus: 24,
    }
}

fn shared_prefix_tenant(requests: usize, rate: f64) -> TenantSpec {
    TenantSpec {
        name: "chat-shared-prefix".into(),
        kind: ScenarioKind::SharedPrefix,
        arrival: ArrivalProcess::Poisson { rate },
        prompt: LengthDist::LogNormal { median: 512, sigma: 0.3, min: 256, max: 1024 },
        decode: LengthDist::Uniform { min: 8, max: 32 },
        requests,
        prefix_groups: 8,
        suffix: LengthDist::Uniform { min: 32, max: 192 },
        rag_corpus: 0,
    }
}

fn needle_tenant(requests: usize) -> TenantSpec {
    TenantSpec {
        name: "needle-probe".into(),
        kind: ScenarioKind::Needle,
        arrival: ArrivalProcess::Ramp { start_rate: 2.0, end_rate: 16.0, ramp_s: 8.0 },
        prompt: LengthDist::Uniform { min: 512, max: 1536 },
        decode: LengthDist::Uniform { min: 2, max: 8 },
        requests,
        prefix_groups: 0,
        suffix: LengthDist::Fixed { tokens: 1 },
        rag_corpus: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn named_scenarios_generate_and_are_deterministic() {
        for name in ["long-doc", "rag", "shared-prefix", "needle", "mixed"] {
            let cfg = named_scenario(name, 64, 5).unwrap();
            let a = cfg.generate().unwrap();
            let b = cfg.generate().unwrap();
            assert_eq!(a, b, "{name} not deterministic");
            assert_eq!(a.len(), cfg.total_requests());
            assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
            assert!(a.iter().enumerate().all(|(i, r)| r.id == i as u64));
            assert_eq!(stream_digest(&a), stream_digest(&b));
        }
        assert!(named_scenario("nope", 64, 5).is_err());
    }

    #[test]
    fn prompts_fit_the_serving_envelope() {
        for name in ["long-doc", "rag", "shared-prefix", "needle", "mixed"] {
            let cfg = named_scenario(name, 128, 11).unwrap();
            assert!(cfg.max_prompt_tokens() <= 2048 - 64, "{name} overflows max_seq");
            for r in cfg.generate().unwrap() {
                assert!(r.prompt_tokens >= 16 && r.prompt_tokens <= 2048 - 64, "{name}: {r:?}");
                assert!(r.decode_tokens >= 1);
            }
        }
    }

    #[test]
    fn shared_prefix_groups_reuse_identical_prefixes() {
        let cfg = named_scenario("shared-prefix", 96, 3).unwrap();
        let reqs = cfg.generate().unwrap();
        let mut by_group: HashMap<u32, Vec<&ScenarioRequest>> = HashMap::new();
        for r in &reqs {
            by_group.entry(r.prefix_group.unwrap()).or_default().push(r);
        }
        assert!(by_group.len() > 1, "expected multiple prefix groups");
        for (g, members) in &by_group {
            let p0 = members[0].prefix_tokens;
            assert!(p0 > 0);
            assert!(
                members.iter().all(|r| r.prefix_tokens == p0),
                "group {g} prefix lengths differ"
            );
            let k0 = members[0].reuse_key;
            assert!(members.iter().all(|r| r.reuse_key == k0));
        }
    }

    #[test]
    fn needle_reuse_keys_are_unique() {
        let cfg = named_scenario("needle", 200, 9).unwrap();
        let reqs = cfg.generate().unwrap();
        let mut keys: Vec<u64> = reqs.iter().map(|r| r.reuse_key).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), reqs.len());
    }

    #[test]
    fn rag_reuse_keys_cycle_a_small_corpus() {
        let cfg = named_scenario("rag", 200, 9).unwrap();
        let reqs = cfg.generate().unwrap();
        let mut keys: Vec<u64> = reqs.iter().map(|r| r.reuse_key).collect();
        keys.sort_unstable();
        keys.dedup();
        assert!(keys.len() <= 24, "corpus leaked: {} distinct keys", keys.len());
        assert!(keys.len() > 4);
    }

    #[test]
    fn heavy_tail_distributions_respect_bounds() {
        let mut rng = Pcg64::seeded(1);
        let ln = LengthDist::LogNormal { median: 768, sigma: 0.45, min: 256, max: 1536 };
        let bp = LengthDist::BoundedPareto { alpha: 1.3, min: 128, max: 1024 };
        for _ in 0..5000 {
            let a = ln.sample(&mut rng);
            assert!((256..=1536).contains(&a));
            let b = bp.sample(&mut rng);
            assert!((128..=1024).contains(&b));
        }
        // Bounded Pareto mass concentrates near the lower bound.
        let mut rng = Pcg64::seeded(2);
        let samples: Vec<usize> = (0..5000).map(|_| bp.sample(&mut rng)).collect();
        let below_256 = samples.iter().filter(|&&x| x < 256).count();
        assert!(below_256 > samples.len() / 2, "pareto tail too light: {below_256}");
    }

    #[test]
    fn validation_rejects_bad_specs() {
        assert!(ScenarioConfig { seed: 0, tenants: vec![] }.validate().is_err());
        let mut t = needle_tenant(10);
        t.requests = 0;
        assert!(ScenarioConfig { seed: 0, tenants: vec![t] }.validate().is_err());
        let mut t = shared_prefix_tenant(10, 4.0);
        t.prefix_groups = 0;
        assert!(ScenarioConfig { seed: 0, tenants: vec![t] }.validate().is_err());
        assert!(LengthDist::Uniform { min: 9, max: 3 }.validate().is_err());
        assert!(LengthDist::BoundedPareto { alpha: 0.0, min: 1, max: 2 }.validate().is_err());
    }
}

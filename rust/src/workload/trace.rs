//! Serving trace generation for the coordinator benchmarks: Poisson
//! arrivals with a long-context-skewed prompt-length mixture, matching the
//! prefill-heavy regime the paper targets. Richer multi-tenant scenario
//! traces live in [`super::scenario`].

use anyhow::{bail, Result};

use crate::util::rng::Pcg64;

/// One synthetic request in a trace.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRequest {
    pub id: u64,
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    pub prompt_tokens: usize,
    pub decode_tokens: usize,
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Mean request rate (req/s).
    pub rate: f64,
    pub num_requests: usize,
    /// (prompt_len, weight) mixture components.
    pub length_mix: Vec<(usize, f64)>,
    pub decode_min: usize,
    pub decode_max: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            rate: 2.0,
            num_requests: 64,
            // Long-context-skewed mixture (the paper's regime).
            length_mix: vec![(512, 0.25), (2048, 0.35), (8192, 0.3), (32768, 0.1)],
            decode_min: 8,
            decode_max: 64,
            seed: 0,
        }
    }
}

impl TraceConfig {
    /// Validate the trace parameters, mirroring the `shards: 0` config
    /// precedent: a descriptive `Err` at parse/CLI time instead of a panic
    /// deep inside generation.
    pub fn validate(&self) -> Result<()> {
        if self.length_mix.is_empty() {
            bail!("trace length_mix must be non-empty");
        }
        if self.length_mix.iter().any(|&(len, w)| len == 0 || !w.is_finite() || w <= 0.0) {
            bail!("trace length_mix entries need len > 0 and weight > 0");
        }
        if !self.rate.is_finite() || self.rate <= 0.0 {
            bail!("trace rate must be > 0 (got {})", self.rate);
        }
        if self.decode_min > self.decode_max {
            bail!(
                "trace decode_min ({}) must be <= decode_max ({})",
                self.decode_min,
                self.decode_max
            );
        }
        Ok(())
    }
}

/// Generate a trace with Poisson arrivals and mixture-sampled lengths.
/// Returns `Err` (not a panic) on invalid configs — see
/// [`TraceConfig::validate`].
pub fn generate_trace(cfg: &TraceConfig) -> Result<Vec<TraceRequest>> {
    cfg.validate()?;
    let total_w: f64 = cfg.length_mix.iter().map(|x| x.1).sum();
    let mut rng = Pcg64::seeded(cfg.seed ^ 0x7ace);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(cfg.num_requests);
    for id in 0..cfg.num_requests {
        t += rng.exponential(cfg.rate);
        // Sample mixture component.
        let mut pick = rng.next_f64() * total_w;
        let mut prompt = cfg.length_mix[0].0;
        for &(len, w) in &cfg.length_mix {
            if pick < w {
                prompt = len;
                break;
            }
            pick -= w;
        }
        // Jitter ±25% around the component length.
        let jitter = 0.75 + 0.5 * rng.next_f64();
        let prompt_tokens = ((prompt as f64 * jitter) as usize).max(16);
        let decode_tokens = cfg.decode_min
            + rng.next_below((cfg.decode_max - cfg.decode_min + 1) as u64) as usize;
        out.push(TraceRequest { id: id as u64, arrival_s: t, prompt_tokens, decode_tokens });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let cfg = TraceConfig::default();
        let a = generate_trace(&cfg).unwrap();
        let b = generate_trace(&cfg).unwrap();
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert_eq!(a.len(), cfg.num_requests);
    }

    #[test]
    fn rate_roughly_respected() {
        let cfg = TraceConfig { rate: 10.0, num_requests: 2000, ..Default::default() };
        let t = generate_trace(&cfg).unwrap();
        let span = t.last().unwrap().arrival_s;
        let measured = cfg.num_requests as f64 / span;
        assert!((measured - 10.0).abs() < 1.5, "measured rate {measured}");
    }

    #[test]
    fn lengths_within_mixture_envelope() {
        let cfg = TraceConfig::default();
        for r in generate_trace(&cfg).unwrap() {
            assert!(r.prompt_tokens >= 16);
            assert!(r.prompt_tokens <= (32768_f64 * 1.25) as usize);
            assert!(r.decode_tokens >= cfg.decode_min && r.decode_tokens <= cfg.decode_max);
        }
    }

    #[test]
    fn invalid_configs_err_instead_of_panicking() {
        let empty = TraceConfig { length_mix: vec![], ..Default::default() };
        assert!(generate_trace(&empty).is_err());
        let bad_rate = TraceConfig { rate: 0.0, ..Default::default() };
        assert!(bad_rate.validate().is_err());
        let bad_rate = TraceConfig { rate: -3.0, ..Default::default() };
        assert!(bad_rate.validate().is_err());
        let bad_decode = TraceConfig { decode_min: 64, decode_max: 8, ..Default::default() };
        let err = generate_trace(&bad_decode).unwrap_err().to_string();
        assert!(err.contains("decode_min"), "unexpected error: {err}");
        let bad_weight =
            TraceConfig { length_mix: vec![(512, 0.0)], ..Default::default() };
        assert!(bad_weight.validate().is_err());
    }
}

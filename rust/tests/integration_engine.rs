//! Cross-layer integration: the Rust engine's method set vs the synthetic
//! workload's ground truth, plus serving-stack integration over the mock
//! engine at scale. No artifacts required.

use anchor_attention::attention::anchor::AnchorConfig;
use anchor_attention::attention::exec::ExecutorKind;
use anchor_attention::attention::plan::BatchInput;
use anchor_attention::attention::shard::ShardedSession;
use anchor_attention::attention::{Method, TileConfig};
use anchor_attention::coordinator::batcher::EngineBatch;
use anchor_attention::coordinator::engine::{MockEngine, StepExecutor, StepOutcome};
use anchor_attention::coordinator::request::Request;
use anchor_attention::coordinator::scheduler::{CostConstants, SparsityModel};
use anchor_attention::coordinator::server::{serve, ServerConfig};
use anchor_attention::experiments::common::{evaluate, gqa_batch, gqa_keys, paper_methods};
use anchor_attention::workload::qkv::{generate, generate_with_needle};
use anchor_attention::workload::trace::{generate_trace, TraceConfig};
use anchor_attention::workload::WorkloadProfile;

/// All five paper methods run end-to-end on one structured head and
/// produce internally-consistent metrics.
#[test]
fn method_set_metrics_consistent() {
    let tile = TileConfig::new(128, 128);
    let n = 4096;
    let wl = generate(&WorkloadProfile::llama_like(), n, 123);
    for m in paper_methods(n, tile, 12.0) {
        let e = evaluate(&wl.head, &m, tile);
        assert!((0.0..=1.0 + 1e-9).contains(&e.recall), "{}: recall {}", e.method, e.recall);
        assert!((0.0..=1.0).contains(&e.sparsity), "{}: sparsity {}", e.method, e.sparsity);
        assert!(e.output_rel_err.is_finite());
        if e.method == "full-attn" {
            assert!(e.recall > 1.0 - 1e-9);
            assert!(e.output_rel_err < 1e-5);
        } else {
            // Sparse methods must actually skip work on a structured head.
            assert!(e.sparsity > 0.0, "{} has zero sparsity", e.method);
        }
        // Output error shrinks as recall grows (coarse consistency).
        if e.recall > 0.99 {
            assert!(e.output_rel_err < 0.25, "{}: err {} at recall {}", e.method, e.output_rel_err, e.recall);
        }
    }
}

/// Anchor recall beats every static baseline at matched-or-better
/// sparsity on the needle workload (the paper's central comparison).
#[test]
fn anchor_beats_streaming_on_needle_workload() {
    let tile = TileConfig::new(128, 128);
    let n = 4096;
    let wl = generate_with_needle(&WorkloadProfile::llama_like(), n, 321, Some(0.4));
    let methods = paper_methods(n, tile, 12.0);
    let evals: Vec<_> = methods.iter().map(|m| evaluate(&wl.head, m, tile)).collect();
    let anchor = evals.iter().find(|e| e.method == "anchor").unwrap();
    let streaming = evals.iter().find(|e| e.method == "streaming-llm").unwrap();
    assert!(anchor.recall > streaming.recall, "{} vs {}", anchor.recall, streaming.recall);
    assert!(anchor.recall > 0.9, "anchor recall {}", anchor.recall);
}

/// A 200-request trace at realistic mixture served through the full
/// control plane (mock engine): conservation + ordering invariants.
#[test]
fn large_trace_serves_to_completion() {
    let trace_cfg = TraceConfig {
        rate: 50.0,
        num_requests: 200,
        length_mix: vec![(128, 0.5), (512, 0.3), (1024, 0.2)],
        decode_min: 1,
        decode_max: 6,
        seed: 5,
    };
    let trace = generate_trace(&trace_cfg).unwrap();
    let requests: Vec<Request> = trace
        .iter()
        .map(|t| Request::new(t.id, vec![1; t.prompt_tokens.min(1900)], t.decode_tokens, t.arrival_s))
        .collect();
    let expect: std::collections::HashMap<u64, usize> =
        requests.iter().map(|r| (r.id, r.max_new_tokens)).collect();

    let mut engine = MockEngine::new(512);
    let cfg = ServerConfig { pool_pages: 512, ..Default::default() };
    let report = serve(&cfg, requests, &mut engine, |_, _| {}).unwrap();
    assert_eq!(report.records.len(), 200);
    for r in &report.records {
        assert_eq!(r.generated_tokens, expect[&r.id], "request {}", r.id);
    }
    assert!(report.iterations > 0);
    assert!(report.decode_throughput() > 0.0);
}

/// An engine that actually runs attention: every executed iteration
/// drives a sharded session over a fixed GQA batch and reports the merged
/// `SessionOutput::hit_rate()` through
/// `StepExecutor::observed_plan_hit_rate` — the live side of the
/// scheduler's amortization prior (DESIGN.md §12).
struct SessionBackedEngine {
    inner: MockEngine,
    session: ShardedSession,
    batch: BatchInput,
    last_hit_rate: Option<f64>,
}

impl StepExecutor for SessionBackedEngine {
    fn execute(&mut self, batch: &EngineBatch) -> Vec<StepOutcome> {
        let out = self.session.run_batch(&self.batch).expect("session batch");
        self.last_hit_rate = Some(out.hit_rate());
        self.inner.execute(batch)
    }

    fn finish_request(&mut self, req: u64) {
        self.inner.finish_request(req);
    }

    fn observed_plan_hit_rate(&mut self) -> Option<f64> {
        self.last_hit_rate.take()
    }
}

/// The serve loop's merged `SessionOutput::hit_rate()` moves the
/// scheduler's `plan_hit_rate` EWMA live: before this wiring only the
/// store-populated 1.0 prior was ever fed. The first engine batch misses
/// half its keys (GQA groups of 2) and every later batch is all hits, so
/// the EWMA must climb from its cold 0.0 toward 1.0 during the run.
#[test]
fn serve_loop_feeds_live_hit_rate_into_the_scheduler_ewma() {
    let profile = WorkloadProfile::llama_like();
    let batch = gqa_batch(&profile, 256, 4, 2, 9);
    let keys = gqa_keys(0, 4, 2);
    let method = Method::Anchor(AnchorConfig {
        tile: TileConfig::new(16, 16),
        theta: 4.0,
        step: 2,
        init_blocks: 1,
        use_anchor: true,
    });
    let session = method.sharded_session(2).keys(keys).build().unwrap();
    let mut engine = SessionBackedEngine {
        inner: MockEngine::new(512),
        session,
        batch,
        last_hit_rate: None,
    };
    let mut cfg = ServerConfig { pool_pages: 128, ..Default::default() };
    cfg.scheduler.sparsity = SparsityModel::Anchor {
        stripe_keep: 0.1,
        anchor_tokens: 256,
        plan_hit_rate: 0.0,
        speculative_hit_rate: 0.0,
        pipelined: false,
        executor: ExecutorKind::Cpu,
        shards: 2,
        constants: CostConstants::modeled(),
    };
    let requests: Vec<Request> =
        (0..4).map(|i| Request::new(i, vec![1; 600], 3, 0.0)).collect();
    let report = serve(&cfg, requests, &mut engine, |_, _| {}).unwrap();
    assert_eq!(report.records.len(), 4);
    assert!(
        report.plan_hit_observations >= 2,
        "several iterations must observe a merged hit rate (got {})",
        report.plan_hit_observations
    );
    let final_rate = report.final_plan_hit_rate.expect("anchor model carries the EWMA");
    assert!(
        final_rate > 0.2,
        "live observations must move the EWMA off its cold prior (got {final_rate})"
    );
    // The warm steady state dominates: with every post-first batch at
    // hit rate 1.0 and EWMA weight 0.5, three observations already put
    // the estimate above the single-observation floor.
    if report.plan_hit_observations >= 3 {
        assert!(final_rate > 0.5, "EWMA should approach the warm rate (got {final_rate})");
    }
    // A dense scheduler ignores observations and reports no EWMA.
    let mut dense_engine = MockEngine::new(512);
    let dense_cfg = ServerConfig { pool_pages: 128, ..Default::default() };
    let dense_report = serve(
        &dense_cfg,
        (0..2).map(|i| Request::new(i, vec![1; 300], 2, 0.0)).collect(),
        &mut dense_engine,
        |_, _| {},
    )
    .unwrap();
    assert_eq!(dense_report.final_plan_hit_rate, None);
    assert_eq!(dense_report.plan_hit_observations, 0);
}

/// The anchor-aware scheduler serves the same trace in no more iterations
/// than the dense scheduler (the paper's speedup as scheduler headroom).
#[test]
fn anchor_scheduler_no_worse_than_dense() {
    let mk_requests = || -> Vec<Request> {
        (0..10).map(|i| Request::new(i, vec![1; 1600], 2, 0.0)).collect()
    };
    let run = |sparsity| {
        let mut engine = MockEngine::new(512);
        let mut cfg = ServerConfig { pool_pages: 512, ..Default::default() };
        cfg.scheduler.sparsity = sparsity;
        cfg.scheduler.iter_budget = 500.0;
        serve(&cfg, mk_requests(), &mut engine, |_, _| {}).unwrap()
    };
    let dense = run(SparsityModel::Dense);
    let anchor = run(SparsityModel::Anchor {
        stripe_keep: 0.08,
        anchor_tokens: 256,
        plan_hit_rate: 0.5,
        speculative_hit_rate: 0.0,
        pipelined: false,
        executor: ExecutorKind::Cpu,
        shards: 1,
        constants: CostConstants::modeled(),
    });
    let piped = run(SparsityModel::Anchor {
        stripe_keep: 0.08,
        anchor_tokens: 256,
        plan_hit_rate: 0.5,
        speculative_hit_rate: 0.0,
        pipelined: true,
        executor: ExecutorKind::Cpu,
        shards: 1,
        constants: CostConstants::modeled(),
    });
    assert!(
        anchor.iterations <= dense.iterations,
        "anchor {} vs dense {}",
        anchor.iterations,
        dense.iterations
    );
    assert!(
        piped.iterations <= anchor.iterations,
        "pipelined {} vs sequential {}",
        piped.iterations,
        anchor.iterations
    );
}

//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! Require `make artifacts` to have run; each test skips (with a notice)
//! when `artifacts/manifest.json` is absent so `cargo test` stays green in
//! a fresh checkout.

use std::rc::Rc;

use anchor_attention::attention::anchor::AnchorConfig;
use anchor_attention::attention::{HeadInput, TileConfig};
use anchor_attention::coordinator::engine::PjrtEngine;
use anchor_attention::coordinator::request::Request;
use anchor_attention::coordinator::server::{serve, ServerConfig};
use anchor_attention::model::LmModel;
use anchor_attention::runtime::{literal_f32, Runtime};
use anchor_attention::tensor::Mat;
use anchor_attention::util::rng::Pcg64;

fn artifact_dir() -> Option<String> {
    let dir = std::env::var("ANCHOR_ATTN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts at {dir} (run `make artifacts`)");
        None
    }
}

fn rand_head(seed: u64, n: usize, d: usize) -> HeadInput {
    let mut rng = Pcg64::seeded(seed);
    HeadInput::new(
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
    )
}

#[test]
fn manifest_loads_and_validates() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    rt.manifest().validate().unwrap();
    assert!(rt.manifest().artifact("attn_full_256").is_some());
    assert!(rt.manifest().artifact("attn_anchor_256").is_some());
    assert_eq!(rt.platform(), "cpu");
}

/// The AOT `attn_full_256` HLO must reproduce the Rust engine's dense
/// attention bit-for-bit (same math, different substrate).
#[test]
fn hlo_full_attention_matches_rust_engine() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let n = 256;
    let d = 64;
    let h = rand_head(1001, n, d);

    let out = rt
        .execute(
            "attn_full_256",
            &[
                literal_f32(&[n, d], &h.q.data).unwrap(),
                literal_f32(&[n, d], &h.k.data).unwrap(),
                literal_f32(&[n, d], &h.v.data).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 1);
    let hlo_out = Mat::from_vec(n, d, out[0].to_vec::<f32>().unwrap());

    let rust_out =
        anchor_attention::attention::full::full_attention(&h, TileConfig::new(64, 64));
    let diff = hlo_out.max_abs_diff(&rust_out.out);
    assert!(diff < 1e-3, "HLO vs engine max diff {diff}");
}

/// The AOT `attn_anchor_256` (Pallas Alg. 1-3) must match the Rust
/// engine's anchor pipeline at the manifest's hyperparameters — the
/// three-layer consistency check of the whole reproduction.
#[test]
fn hlo_anchor_attention_matches_rust_engine() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let spec = rt.manifest().anchor;
    let n = 256;
    let d = 64;
    let h = rand_head(1002, n, d);

    let out = rt
        .execute(
            "attn_anchor_256",
            &[
                literal_f32(&[n, d], &h.q.data).unwrap(),
                literal_f32(&[n, d], &h.k.data).unwrap(),
                literal_f32(&[n, d], &h.v.data).unwrap(),
            ],
        )
        .unwrap();
    let hlo_out = Mat::from_vec(n, d, out[0].to_vec::<f32>().unwrap());

    let cfg = AnchorConfig {
        tile: TileConfig::new(spec.block, spec.block),
        theta: spec.theta as f32,
        step: spec.step,
        init_blocks: spec.init_blocks,
        use_anchor: true,
    };
    let rust_out = anchor_attention::attention::anchor::anchor_attention(&h, &cfg);
    let diff = hlo_out.max_abs_diff(&rust_out.out);
    assert!(diff < 1e-3, "anchor HLO vs engine max diff {diff}");
}

#[test]
fn lm_prefill_decode_roundtrip() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Rc::new(Runtime::open(&dir).unwrap());
    let model = LmModel::load(rt).unwrap();
    let mut session = model.new_session().unwrap();

    let prompt: Vec<i32> = (0..300).map(|i| (i * 7) % model.vocab as i32).collect();
    let logits = model.prefill(&mut session, &prompt).unwrap();
    assert_eq!(logits.len(), model.vocab);
    assert!(logits.iter().all(|x| x.is_finite()));
    assert_eq!(session.pos, 300);

    let tok = anchor_attention::model::argmax(&logits);
    let logits2 = model.decode(&mut session, tok).unwrap();
    assert_eq!(logits2.len(), model.vocab);
    assert_eq!(session.pos, 301);
}

/// Chunked prefill must match whole-prompt prefill (KV-cache exactness
/// across the Rust↔PJRT boundary).
#[test]
fn chunked_prefill_consistency() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Rc::new(Runtime::open(&dir).unwrap());
    let model = LmModel::load(rt).unwrap();

    let prompt: Vec<i32> = (0..272).map(|i| (i * 13 + 5) % model.vocab as i32).collect();

    // One pass (single call handles chunking internally: 256 + 16).
    let mut s1 = model.new_session().unwrap();
    let l1 = model.prefill(&mut s1, &prompt).unwrap();

    // Two explicit calls at a different split (128 + 144).
    let mut s2 = model.new_session().unwrap();
    let _ = model.prefill(&mut s2, &prompt[..128]).unwrap();
    let l2 = model.prefill(&mut s2, &prompt[128..]).unwrap();

    let max_diff = l1
        .iter()
        .zip(&l2)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "chunk-split changed logits by {max_diff}");
}

#[test]
fn end_to_end_serve_small_trace_on_pjrt() {
    let Some(dir) = artifact_dir() else { return };
    let mut engine = PjrtEngine::new(&dir).unwrap();
    let vocab = engine.vocab() as i32;

    let trace: Vec<Request> = (0..3)
        .map(|i| {
            let prompt: Vec<i32> = (0..200 + i * 50).map(|t| (t as i32 * 3) % vocab).collect();
            Request::new(i as u64, prompt, 3, 0.0)
        })
        .collect();

    let cfg = ServerConfig::default();
    let report = serve(&cfg, trace, &mut engine, |e, r| {
        e.register(r.id, r.prompt.clone());
    })
    .unwrap();

    assert_eq!(report.records.len(), 3);
    for r in &report.records {
        assert_eq!(r.generated_tokens, 3, "request {} incomplete", r.id);
        assert!(r.ttft_s.is_finite());
    }
    assert!(report.engine_busy_s > 0.0);
}

//! Property-based tests over the coordinator invariants (routing,
//! batching, KV-pool state) and the attention-engine metamorphic
//! properties, using the in-crate mini proptest harness.

use anchor_attention::attention::anchor::{anchor_attention, AnchorConfig};
use anchor_attention::attention::{Method, TileConfig};
use anchor_attention::coordinator::engine::MockEngine;
use anchor_attention::coordinator::kv_cache::PagePool;
use anchor_attention::coordinator::request::Request;
use anchor_attention::coordinator::request::RequestState;
use anchor_attention::coordinator::scheduler::{plan_iteration, SchedulerConfig};
use anchor_attention::coordinator::server::{serve, ServerConfig};
use anchor_attention::tensor::Mat;
use anchor_attention::util::proptest::{check, ensure, shrink_vec, Config};
use anchor_attention::util::rng::Pcg64;
use anchor_attention::workload::qkv::generate;
use anchor_attention::workload::WorkloadProfile;

/// A random request mix (prompt length, decode tokens).
fn gen_mix(rng: &mut Pcg64) -> Vec<(usize, usize)> {
    let n = 1 + rng.next_below(12) as usize;
    (0..n)
        .map(|_| {
            let prompt = 16 + rng.next_below(1500) as usize;
            let decode = 1 + rng.next_below(8) as usize;
            (prompt, decode)
        })
        .collect()
}

fn shrink_mix(xs: &Vec<(usize, usize)>) -> Vec<Vec<(usize, usize)>> {
    shrink_vec(xs, |&(p, d)| {
        let mut out = Vec::new();
        if p > 16 {
            out.push((p / 2 + 8, d));
        }
        if d > 1 {
            out.push((p, d / 2));
        }
        out
    })
}

/// Every request in every mix is served to completion with exactly
/// `max_new_tokens` outputs, and latencies are ordered.
#[test]
fn prop_server_completes_every_mix() {
    let cfg = Config { cases: 40, seed: 0xA11CE, ..Default::default() };
    check(&cfg, gen_mix, shrink_mix, |mix| {
        let trace: Vec<Request> = mix
            .iter()
            .enumerate()
            .map(|(i, &(p, d))| Request::new(i as u64, vec![1; p], d, 0.0))
            .collect();
        let mut engine = MockEngine::new(512);
        let server_cfg = ServerConfig { pool_pages: 96, ..Default::default() };
        let report = serve(&server_cfg, trace, &mut engine, |_, _| {})
            .map_err(|e| format!("serve failed: {e}"))?;
        ensure(report.records.len() == mix.len(), "record count mismatch")?;
        for r in &report.records {
            let (p, d) = mix[r.id as usize];
            ensure(r.prompt_tokens == p, format!("req {}: prompt {} != {p}", r.id, r.prompt_tokens))?;
            ensure(
                r.generated_tokens == d,
                format!("req {}: generated {} != {d}", r.id, r.generated_tokens),
            )?;
            ensure(r.ttft_s.is_finite() && r.e2e_s >= r.ttft_s - 1e-9, "latency ordering")?;
        }
        Ok(())
    });
}

/// Scheduler invariants: a plan never double-schedules a request, never
/// exceeds remaining prefill, and chunk sizes respect the configured cap.
#[test]
fn prop_scheduler_plan_well_formed() {
    let cfg = Config { cases: 60, seed: 0xBEEF, ..Default::default() };
    check(&cfg, gen_mix, shrink_mix, |mix| {
        let mut states: Vec<RequestState> = mix
            .iter()
            .enumerate()
            .map(|(i, &(p, d))| RequestState::new(Request::new(i as u64, vec![1; p], d, 0.0)))
            .collect();
        let mut pool = PagePool::new(48, 64);
        let sched = SchedulerConfig::default();
        for _ in 0..8 {
            let free_before = pool.free_pages();
            let plan = plan_iteration(&sched, &mut states, &mut pool);
            let mut seen = std::collections::HashSet::new();
            for &(id, take) in &plan.prefill {
                ensure(seen.insert(id), format!("request {id} planned twice"))?;
                let st = states.iter().find(|s| s.request.id == id).unwrap();
                ensure(take >= 1 && take <= st.remaining_prefill(), "chunk bounds")?;
                ensure(take <= sched.chunk, "chunk size cap")?;
            }
            for &id in &plan.decode {
                ensure(seen.insert(id), format!("request {id} planned twice (decode)"))?;
            }
            ensure(pool.free_pages() <= free_before, "pool can only shrink during planning")?;
            // Apply progress to advance the simulation.
            for &(id, take) in &plan.prefill {
                let st = states.iter_mut().find(|s| s.request.id == id).unwrap();
                st.prefilled += take;
                if st.remaining_prefill() == 0 {
                    st.phase = anchor_attention::coordinator::request::Phase::Decode;
                    st.generated.push(1);
                }
            }
            for &id in &plan.decode {
                let st = states.iter_mut().find(|s| s.request.id == id).unwrap();
                st.generated.push(1);
                if st.decode_done() {
                    st.phase = anchor_attention::coordinator::request::Phase::Finished;
                    pool.release(id).map_err(|e| e.to_string())?;
                }
            }
        }
        Ok(())
    });
}

/// Page pool conservation: random admit/release sequences never lose or
/// duplicate pages.
#[test]
fn prop_page_pool_conservation() {
    let cfg = Config { cases: 60, seed: 0xD00D, ..Default::default() };
    let gen = |rng: &mut Pcg64| -> Vec<(u8, u64, usize)> {
        (0..rng.next_below(30) as usize + 1)
            .map(|_| {
                (
                    rng.next_below(2) as u8,
                    rng.next_below(6),
                    rng.next_below(600) as usize + 1,
                )
            })
            .collect()
    };
    check(&cfg, gen, |xs| shrink_vec(xs, |_| vec![]), |ops| {
        let total = 32;
        let mut pool = PagePool::new(total, 64);
        let mut live = std::collections::HashSet::new();
        for &(op, seq, tokens) in ops {
            match op {
                0 => {
                    if !live.contains(&seq) && pool.can_admit(tokens) {
                        pool.admit(seq, tokens).map_err(|e| e.to_string())?;
                        live.insert(seq);
                    }
                }
                _ => {
                    if live.remove(&seq) {
                        pool.release(seq).map_err(|e| e.to_string())?;
                    }
                }
            }
            ensure(
                pool.free_pages() + pool.used_pages() == total,
                format!("page leak: {} + {} != {total}", pool.free_pages(), pool.used_pages()),
            )?;
        }
        Ok(())
    });
}

/// Metamorphic attention property: recall never decreases when θ grows.
#[test]
fn prop_anchor_recall_monotone_in_theta() {
    let cfg = Config { cases: 8, seed: 0xFEED, ..Default::default() };
    let gen = |rng: &mut Pcg64| rng.next_u64();
    check(&cfg, gen, |_| vec![], |&seed| {
        let tile = TileConfig::new(64, 64);
        let wl = generate(&WorkloadProfile::llama_like(), 1024, seed);
        let mut last = -1.0f64;
        for theta in [0.0f32, 6.0, 12.0, 1e9] {
            let c = AnchorConfig { tile, theta, step: 4, init_blocks: 1, use_anchor: true };
            let out = anchor_attention(&wl.head, &c);
            let rec =
                anchor_attention::attention::metrics::recall(&wl.head, &out.coverage, tile);
            ensure(
                rec.mean_recall >= last - 1e-9,
                format!("recall fell: {last} -> {} at θ={theta}", rec.mean_recall),
            )?;
            last = rec.mean_recall;
        }
        Ok(())
    });
}

/// Metamorphic: permuting V columns permutes the output identically
/// (attention is linear over the value space).
#[test]
fn prop_value_column_permutation_equivariance() {
    let cfg = Config { cases: 6, seed: 0xCAFE, ..Default::default() };
    check(&cfg, |rng| rng.next_u64(), |_| vec![], |&seed| {
        let tile = TileConfig::new(32, 32);
        let wl = generate(&WorkloadProfile::llama_like(), 256, seed);
        let d = wl.head.d();
        let method = Method::Anchor(AnchorConfig {
            tile,
            theta: 8.0,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        });
        let mut session = method.session().no_cache().build().unwrap();
        let base = session.run(&wl.head).unwrap().into_single();
        let mut v2 = Mat::zeros(wl.head.v.rows, d);
        for r in 0..wl.head.v.rows {
            for c in 0..d {
                v2.set(r, c, wl.head.v.at(r, d - 1 - c));
            }
        }
        let head2 = anchor_attention::attention::HeadInput::new(
            wl.head.q.clone(),
            wl.head.k.clone(),
            v2,
        );
        let permuted = session.run(&head2).unwrap().into_single();
        for r in 0..base.out.rows {
            for c in 0..d {
                let a = base.out.at(r, d - 1 - c);
                let b = permuted.out.at(r, c);
                ensure((a - b).abs() < 1e-5, format!("row {r} col {c}: {a} vs {b}"))?;
            }
        }
        Ok(())
    });
}

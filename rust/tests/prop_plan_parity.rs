//! Planner → executor parity properties (the refactor's acceptance bar):
//!
//! * **Fused parity** — for every method, `execute_plan` on the method's
//!   plan equals exact softmax attention restricted to the plan's coverage
//!   within 1e-4 max-abs-diff (the defining semantics of the old fused
//!   per-head implementations), and the dense plan equals naive attention.
//! * **θ → ∞** — the anchor planner's coverage degenerates to full causal
//!   coverage and its output to dense attention.
//! * **Cost honesty** — `SparsePlan::predicted_cost` equals the executor's
//!   measured tally.
//! * **Batch ≡ single** — the head-parallel batched path reproduces the
//!   per-head path bit-for-bit on outputs.

use anchor_attention::attention::anchor::AnchorConfig;
use anchor_attention::attention::baselines::block_topk::BlockTopKConfig;
use anchor_attention::attention::baselines::flexprefill::FlexPrefillConfig;
use anchor_attention::attention::baselines::streaming::StreamingConfig;
use anchor_attention::attention::baselines::vertical_slash::VerticalSlashConfig;
use anchor_attention::attention::full::naive_attention;
use anchor_attention::attention::plan::{self, masked_reference, BatchInput};
use anchor_attention::attention::{HeadInput, Method, TileConfig};
use anchor_attention::tensor::Mat;
use anchor_attention::util::proptest::{check, choose, ensure, Config};
use anchor_attention::util::rng::Pcg64;
use anchor_attention::workload::qkv::generate;
use anchor_attention::workload::WorkloadProfile;

fn rand_head(rng: &mut Pcg64, n: usize, d: usize) -> HeadInput {
    HeadInput::new(
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
    )
}

/// One random (head, method) parity case.
#[derive(Clone, Debug)]
struct ParityCase {
    seed: u64,
    n: usize,
    d: usize,
    method_idx: usize,
    theta: f32,
    step: usize,
}

fn gen_case(rng: &mut Pcg64) -> ParityCase {
    ParityCase {
        seed: rng.next_u64(),
        n: *choose(rng, &[64, 96, 128, 160]),
        d: *choose(rng, &[8, 16]),
        method_idx: rng.next_below(6) as usize,
        theta: *choose(rng, &[-2.0, 0.5, 3.0, 8.0]),
        step: *choose(rng, &[1, 2, 4]),
    }
}

fn shrink_case(c: &ParityCase) -> Vec<ParityCase> {
    let mut out = Vec::new();
    if c.n > 64 {
        out.push(ParityCase { n: 64, ..c.clone() });
    }
    if c.step > 1 {
        out.push(ParityCase { step: 1, ..c.clone() });
    }
    if c.d > 8 {
        out.push(ParityCase { d: 8, ..c.clone() });
    }
    out
}

fn method_for(c: &ParityCase) -> Method {
    let tile = TileConfig::new(16, 16);
    match c.method_idx {
        0 => Method::Full(tile),
        1 => Method::Anchor(AnchorConfig {
            tile,
            theta: c.theta,
            step: c.step,
            init_blocks: 1,
            use_anchor: c.seed % 2 == 0,
        }),
        2 => Method::Streaming(StreamingConfig {
            tile,
            global_tokens: 16,
            local_tokens: 32,
        }),
        3 => Method::VerticalSlash(VerticalSlashConfig {
            tile,
            vertical_tokens: 8,
            slash_tokens: 8,
            last_q: 16,
        }),
        4 => Method::FlexPrefill(FlexPrefillConfig {
            tile,
            gamma: 0.85,
            min_budget_tokens: 16,
        }),
        _ => Method::BlockTopK(BlockTopKConfig { tile, k: 3, force_sink_local: true }),
    }
}

/// (a) Every method's executed plan equals the coverage-masked softmax
/// reference within 1e-4, and predicted cost equals measured cost.
#[test]
fn prop_execute_plan_matches_masked_softmax_for_all_methods() {
    let cfg = Config::heavy(24, 0x9A17);
    check(&cfg, gen_case, shrink_case, |c| {
        let mut rng = Pcg64::seeded(c.seed);
        let h = rand_head(&mut rng, c.n, c.d);
        let m = method_for(c);
        let head_plan = m.plan(&h);
        let out = plan::execute_plan(&h, &head_plan);
        ensure(
            head_plan.predicted_cost == out.cost,
            format!("{}: predicted {:?} != measured {:?}", m.name(), head_plan.predicted_cost, out.cost),
        )?;
        let expect = masked_reference(&h, &out.coverage);
        let diff = out.out.max_abs_diff(&expect);
        ensure(diff < 1e-4, format!("{}: masked-softmax diff {diff}", m.name()))?;
        if matches!(m, Method::Full(_)) {
            let dense = naive_attention(&h);
            let diff = out.out.max_abs_diff(&dense);
            ensure(diff < 1e-4, format!("full-attn vs naive diff {diff}"))?;
        }
        Ok(())
    });
}

/// (b) θ → ∞ anchor plan ≡ full coverage, and the output equals dense
/// attention within 1e-4.
#[test]
fn prop_infinite_theta_anchor_is_full_attention() {
    let cfg = Config::heavy(12, 0x1DEA);
    check(
        &cfg,
        |rng| (rng.next_u64(), *choose(rng, &[64, 128, 160]), *choose(rng, &[1usize, 2, 4])),
        |_| vec![],
        |&(seed, n, step)| {
            let mut rng = Pcg64::seeded(seed);
            let h = rand_head(&mut rng, n, 8);
            let acfg = AnchorConfig {
                tile: TileConfig::new(16, 16),
                theta: f32::INFINITY,
                step,
                init_blocks: 1,
                use_anchor: true,
            };
            let head_plan = Method::Anchor(acfg).plan(&h);
            let cov = head_plan.coverage();
            ensure(
                cov.sparsity() == 0.0,
                format!("θ=∞ coverage not full: sparsity {}", cov.sparsity()),
            )?;
            let full_cov = anchor_attention::attention::mask::Coverage::full(n, 16);
            ensure(
                cov.total_covered() == full_cov.total_covered(),
                "θ=∞ covered-pair count differs from full causal coverage",
            )?;
            let out = plan::execute_plan(&h, &head_plan);
            let dense = naive_attention(&h);
            let diff = out.out.max_abs_diff(&dense);
            ensure(diff < 1e-4, format!("θ=∞ vs dense diff {diff}"))
        },
    );
}

/// Batched head-parallel execution reproduces per-head runs on realistic
/// structured workloads.
#[test]
fn prop_batch_path_matches_single_head_path() {
    let cfg = Config::heavy(6, 0xBA7C);
    check(
        &cfg,
        |rng| rng.next_u64(),
        |_| vec![],
        |&seed| {
            let n = 512;
            let tile = TileConfig::new(64, 64);
            let heads: Vec<HeadInput> = (0..3)
                .map(|i| generate(&WorkloadProfile::llama_like(), n, seed.wrapping_add(i)).head)
                .collect();
            let batch = BatchInput::new(heads.clone());
            let m = Method::Anchor(AnchorConfig {
                tile,
                theta: 6.0,
                step: 2,
                init_blocks: 1,
                use_anchor: true,
            });
            let b = m.run_batch(&batch);
            for (i, h) in heads.iter().enumerate() {
                let single = m.run(h);
                let diff = b.outputs[i].out.max_abs_diff(&single.out);
                ensure(diff < 1e-6, format!("head {i}: batch vs single diff {diff}"))?;
                ensure(
                    b.outputs[i].cost == single.cost,
                    format!("head {i}: cost diverges"),
                )?;
            }
            Ok(())
        },
    );
}

/// Plan coverage is exactly the executed coverage for every method (the
/// metrics pipeline may skip execution entirely).
#[test]
fn prop_plan_coverage_equals_executed_coverage() {
    let cfg = Config::heavy(18, 0xC0FE);
    check(&cfg, gen_case, shrink_case, |c| {
        let mut rng = Pcg64::seeded(c.seed);
        let h = rand_head(&mut rng, c.n, c.d);
        let m = method_for(c);
        let head_plan = m.plan(&h);
        let out = m.run(&h);
        let a = head_plan.coverage();
        let b = &out.coverage;
        ensure(
            a.total_covered() == b.total_covered() && a.sparsity() == b.sparsity(),
            format!("{}: plan coverage != executed coverage", m.name()),
        )
    });
}

//! Planner → executor parity properties (the refactor's acceptance bar):
//!
//! * **Fused parity** — for every method, `execute_plan` on the method's
//!   plan equals exact softmax attention restricted to the plan's coverage
//!   within 1e-4 max-abs-diff (the defining semantics of the old fused
//!   per-head implementations), and the dense plan equals naive attention.
//! * **θ → ∞** — the anchor planner's coverage degenerates to full causal
//!   coverage and its output to dense attention.
//! * **Cost honesty** — `SparsePlan::predicted_cost` equals the executor's
//!   measured tally.
//! * **Batch ≡ single** — the head-parallel batched path reproduces the
//!   per-head path bit-for-bit on outputs.
//! * **Pipelined ≡ sequential** — the async plan pipeline (planners for
//!   head *i+1* overlapped with execution of head *i* through the bounded
//!   plan queue) is bitwise-identical to the sequential planner→executor
//!   path for every method, including cache-hit accounting, and a
//!   panicked planner worker surfaces an error instead of deadlocking.
//! * **Backend parity** — the PJRT gather backend (stub dispatch) is
//!   bitwise-equal to the CPU tile walk for every planner, per head and
//!   batched, sequential and pipelined, over flat K/V and through the
//!   paged-KV route (`PagedKvStore::gather` as the executor's KvSource).

use anchor_attention::attention::anchor::AnchorConfig;
use anchor_attention::attention::exec::{
    CpuTileExecutor, Executor, ExecutorKind, LoweringMode, PjrtGatherExecutor,
};
use anchor_attention::coordinator::kv_cache::{PagedExecutor, PagedKvStore};
use anchor_attention::attention::pipeline::{run_planner_batch_pipelined, PlanPipeline};
use anchor_attention::attention::plan::{PlanKey, Planner, SparsePlan};
use anchor_attention::attention::session::AttentionSession;
use anchor_attention::attention::baselines::block_topk::BlockTopKConfig;
use anchor_attention::attention::baselines::flexprefill::FlexPrefillConfig;
use anchor_attention::attention::baselines::streaming::StreamingConfig;
use anchor_attention::attention::baselines::vertical_slash::VerticalSlashConfig;
use anchor_attention::attention::full::naive_attention;
use anchor_attention::attention::plan::{self, masked_reference, BatchInput};
use anchor_attention::attention::{HeadInput, Method, TileConfig};
use anchor_attention::tensor::Mat;
use anchor_attention::util::proptest::{check, choose, ensure, Config};
use anchor_attention::util::rng::Pcg64;
use anchor_attention::workload::qkv::generate;
use anchor_attention::workload::WorkloadProfile;

fn rand_head(rng: &mut Pcg64, n: usize, d: usize) -> HeadInput {
    HeadInput::new(
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
    )
}

/// Fresh uncached session on the given backend — the session-API
/// equivalent of the old per-call `run`/`run_batch` entry points.
fn uncached_session(m: &Method, kind: ExecutorKind, pipelined: bool) -> AttentionSession {
    let mut b = m.session().no_cache().executor(kind);
    if pipelined {
        b = b.pipelined(true);
    }
    b.build().expect("session build")
}

/// One random (head, method) parity case.
#[derive(Clone, Debug)]
struct ParityCase {
    seed: u64,
    n: usize,
    d: usize,
    method_idx: usize,
    theta: f32,
    step: usize,
}

fn gen_case(rng: &mut Pcg64) -> ParityCase {
    ParityCase {
        seed: rng.next_u64(),
        n: *choose(rng, &[64, 96, 128, 160]),
        d: *choose(rng, &[8, 16]),
        method_idx: rng.next_below(6) as usize,
        theta: *choose(rng, &[-2.0, 0.5, 3.0, 8.0]),
        step: *choose(rng, &[1, 2, 4]),
    }
}

fn shrink_case(c: &ParityCase) -> Vec<ParityCase> {
    let mut out = Vec::new();
    if c.n > 64 {
        out.push(ParityCase { n: 64, ..c.clone() });
    }
    if c.step > 1 {
        out.push(ParityCase { step: 1, ..c.clone() });
    }
    if c.d > 8 {
        out.push(ParityCase { d: 8, ..c.clone() });
    }
    out
}

fn method_for(c: &ParityCase) -> Method {
    let tile = TileConfig::new(16, 16);
    match c.method_idx {
        0 => Method::Full(tile),
        1 => Method::Anchor(AnchorConfig {
            tile,
            theta: c.theta,
            step: c.step,
            init_blocks: 1,
            use_anchor: c.seed % 2 == 0,
        }),
        2 => Method::Streaming(StreamingConfig {
            tile,
            global_tokens: 16,
            local_tokens: 32,
        }),
        3 => Method::VerticalSlash(VerticalSlashConfig {
            tile,
            vertical_tokens: 8,
            slash_tokens: 8,
            last_q: 16,
        }),
        4 => Method::FlexPrefill(FlexPrefillConfig {
            tile,
            gamma: 0.85,
            min_budget_tokens: 16,
        }),
        _ => Method::BlockTopK(BlockTopKConfig { tile, k: 3, force_sink_local: true }),
    }
}

/// (a) Every method's executed plan equals the coverage-masked softmax
/// reference within 1e-4, and predicted cost equals measured cost.
#[test]
fn prop_execute_plan_matches_masked_softmax_for_all_methods() {
    let cfg = Config::heavy(24, 0x9A17);
    check(&cfg, gen_case, shrink_case, |c| {
        let mut rng = Pcg64::seeded(c.seed);
        let h = rand_head(&mut rng, c.n, c.d);
        let m = method_for(c);
        let head_plan = m.plan(&h);
        let out = plan::execute_plan(&h, &head_plan);
        ensure(
            head_plan.predicted_cost == out.cost,
            format!("{}: predicted {:?} != measured {:?}", m.name(), head_plan.predicted_cost, out.cost),
        )?;
        let expect = masked_reference(&h, &out.coverage);
        let diff = out.out.max_abs_diff(&expect);
        ensure(diff < 1e-4, format!("{}: masked-softmax diff {diff}", m.name()))?;
        if matches!(m, Method::Full(_)) {
            let dense = naive_attention(&h);
            let diff = out.out.max_abs_diff(&dense);
            ensure(diff < 1e-4, format!("full-attn vs naive diff {diff}"))?;
        }
        Ok(())
    });
}

/// (b) θ → ∞ anchor plan ≡ full coverage, and the output equals dense
/// attention within 1e-4.
#[test]
fn prop_infinite_theta_anchor_is_full_attention() {
    let cfg = Config::heavy(12, 0x1DEA);
    check(
        &cfg,
        |rng| (rng.next_u64(), *choose(rng, &[64, 128, 160]), *choose(rng, &[1usize, 2, 4])),
        |_| vec![],
        |&(seed, n, step)| {
            let mut rng = Pcg64::seeded(seed);
            let h = rand_head(&mut rng, n, 8);
            let acfg = AnchorConfig {
                tile: TileConfig::new(16, 16),
                theta: f32::INFINITY,
                step,
                init_blocks: 1,
                use_anchor: true,
            };
            let head_plan = Method::Anchor(acfg).plan(&h);
            let cov = head_plan.coverage();
            ensure(
                cov.sparsity() == 0.0,
                format!("θ=∞ coverage not full: sparsity {}", cov.sparsity()),
            )?;
            let full_cov = anchor_attention::attention::mask::Coverage::full(n, 16);
            ensure(
                cov.total_covered() == full_cov.total_covered(),
                "θ=∞ covered-pair count differs from full causal coverage",
            )?;
            let out = plan::execute_plan(&h, &head_plan);
            let dense = naive_attention(&h);
            let diff = out.out.max_abs_diff(&dense);
            ensure(diff < 1e-4, format!("θ=∞ vs dense diff {diff}"))
        },
    );
}

/// Batched head-parallel execution reproduces per-head runs on realistic
/// structured workloads.
#[test]
fn prop_batch_path_matches_single_head_path() {
    let cfg = Config::heavy(6, 0xBA7C);
    check(
        &cfg,
        |rng| rng.next_u64(),
        |_| vec![],
        |&seed| {
            let n = 512;
            let tile = TileConfig::new(64, 64);
            let heads: Vec<HeadInput> = (0..3)
                .map(|i| generate(&WorkloadProfile::llama_like(), n, seed.wrapping_add(i)).head)
                .collect();
            let batch = BatchInput::new(heads.clone());
            let m = Method::Anchor(AnchorConfig {
                tile,
                theta: 6.0,
                step: 2,
                init_blocks: 1,
                use_anchor: true,
            });
            let b = uncached_session(&m, ExecutorKind::Cpu, false)
                .run_batch(&batch)
                .map_err(|e| e.to_string())?;
            for (i, h) in heads.iter().enumerate() {
                let single = uncached_session(&m, ExecutorKind::Cpu, false)
                    .run(h)
                    .map_err(|e| e.to_string())?
                    .into_single();
                let diff = b.outputs[i].out.max_abs_diff(&single.out);
                ensure(diff < 1e-6, format!("head {i}: batch vs single diff {diff}"))?;
                ensure(
                    b.outputs[i].cost == single.cost,
                    format!("head {i}: cost diverges"),
                )?;
            }
            Ok(())
        },
    );
}

/// Pipelined execution is bitwise-identical to the sequential
/// planner→executor path — outputs, costs, and hit accounting — for every
/// method, uncached and cached (deterministic sweep over all six, then a
/// randomized property over shapes/params).
#[test]
fn pipelined_execution_bitwise_equals_sequential_for_all_six_methods() {
    let mut rng = Pcg64::seeded(0xA57C);
    let heads: Vec<HeadInput> = (0..4).map(|_| rand_head(&mut rng, 128, 8)).collect();
    let batch = BatchInput::new(heads);
    let keys = vec![
        PlanKey::new(0, 0),
        PlanKey::new(0, 0),
        PlanKey::new(0, 1),
        PlanKey::new(0, 1),
    ];
    for method_idx in 0..6 {
        let c = ParityCase { seed: 2, n: 128, d: 8, method_idx, theta: 3.0, step: 2 };
        let m = method_for(&c);

        let seq = uncached_session(&m, ExecutorKind::Cpu, false).run_batch(&batch).unwrap();
        let piped = uncached_session(&m, ExecutorKind::Cpu, true)
            .run_batch(&batch)
            .unwrap_or_else(|e| panic!("{}: pipelined run failed: {e}", m.name()));
        assert_eq!(
            (seq.cache_hits, seq.cache_misses),
            (piped.cache_hits, piped.cache_misses),
            "{}: uncached accounting",
            m.name()
        );
        for (h, (a, b)) in seq.outputs.iter().zip(&piped.outputs).enumerate() {
            assert_eq!(a.out.data, b.out.data, "{} head {h}: output not bitwise-equal", m.name());
            assert_eq!(a.cost, b.cost, "{} head {h}: cost differs", m.name());
            assert_eq!(
                a.coverage.total_covered(),
                b.coverage.total_covered(),
                "{} head {h}: coverage differs",
                m.name()
            );
        }

        let mut seq_session = m.session().keys(keys.clone()).build().unwrap();
        let mut pipe_session = m.session().keys(keys.clone()).pipelined(true).build().unwrap();
        let seq_c = seq_session.run_batch(&batch).unwrap();
        let piped_c = pipe_session
            .run_batch(&batch)
            .unwrap_or_else(|e| panic!("{}: cached pipelined run failed: {e}", m.name()));
        assert_eq!(
            (seq_c.cache_hits, seq_c.cache_misses),
            (piped_c.cache_hits, piped_c.cache_misses),
            "{}: cached accounting",
            m.name()
        );
        assert_eq!(
            seq_c.ident_cost_paid,
            piped_c.ident_cost_paid,
            "{}: ident attribution differs",
            m.name()
        );
        for (h, (a, b)) in seq_c.outputs.iter().zip(&piped_c.outputs).enumerate() {
            assert_eq!(
                a.out.data, b.out.data,
                "{} head {h}: cached output not bitwise-equal",
                m.name()
            );
            assert_eq!(a.cost, b.cost, "{} head {h}: cached cost differs", m.name());
        }
    }
}

/// Randomized pipelined-vs-sequential parity across shapes, params, and
/// pipeline depths (reuses the parity-case generator).
#[test]
fn prop_pipelined_batch_bitwise_equals_sequential() {
    let cfg = Config::heavy(10, 0x0F1F);
    check(&cfg, gen_case, shrink_case, |c| {
        let mut rng = Pcg64::seeded(c.seed);
        let heads: Vec<HeadInput> = (0..3).map(|_| rand_head(&mut rng, c.n, c.d)).collect();
        let batch = BatchInput::new(heads);
        let m = method_for(c);
        let pipe = PlanPipeline { depth: 1 + (c.seed % 3) as usize, workers: 1 + (c.step % 3) };
        let seq = uncached_session(&m, ExecutorKind::Cpu, false)
            .run_batch(&batch)
            .map_err(|e| e.to_string())?;
        let piped = m
            .session()
            .no_cache()
            .pipeline(pipe)
            .build()
            .map_err(|e| e.to_string())?
            .run_batch(&batch)
            .map_err(|e| format!("{}: pipelined run failed: {e}", m.name()))?;
        for (h, (a, b)) in seq.outputs.iter().zip(&piped.outputs).enumerate() {
            ensure(
                a.out.data == b.out.data,
                format!("{} head {h}: pipelined output not bitwise-equal", m.name()),
            )?;
            ensure(a.cost == b.cost, format!("{} head {h}: cost differs", m.name()))?;
        }
        ensure(
            piped.pipeline.expect("pipelined stats").items == batch.h(),
            format!("{}: expected one plan item per head", m.name()),
        )
    });
}

/// A planner worker that panics must surface its message as an error
/// instead of deadlocking the bounded plan queue.
#[test]
fn poisoned_planner_worker_errors_instead_of_deadlocking() {
    struct PanicPlanner;
    impl Planner for PanicPlanner {
        fn name(&self) -> &'static str {
            "panic-planner"
        }
        fn plan(&self, _input: &HeadInput) -> SparsePlan {
            panic!("identification worker died");
        }
    }
    let mut rng = Pcg64::seeded(0xDEAD);
    let heads: Vec<HeadInput> = (0..6).map(|_| rand_head(&mut rng, 64, 8)).collect();
    let batch = BatchInput::new(heads);
    for (depth, workers) in [(1, 1), (2, 2), (2, 4)] {
        let pipe = PlanPipeline { depth, workers };
        let err = run_planner_batch_pipelined(
            &PanicPlanner,
            &batch,
            None,
            None,
            &pipe,
            &CpuTileExecutor::default(),
        )
        .expect_err("panicking planner must surface an error");
        assert!(
            err.contains("identification worker died"),
            "depth {depth} workers {workers}: {err}"
        );
    }
}

/// Backend parity, per head: for every method's plan the PJRT gather
/// backend (lowering + stub dispatch + host interpretation) is
/// bitwise-equal to the CPU tile walk, over flat K/V and through the
/// paged-KV route with a non-identity page table.
#[test]
fn prop_executor_backends_bitwise_equal_for_all_planners() {
    let cfg = Config::heavy(12, 0xE7EC);
    check(&cfg, gen_case, shrink_case, |c| {
        let mut rng = Pcg64::seeded(c.seed);
        let h = rand_head(&mut rng, c.n, c.d);
        let m = method_for(c);
        let head_plan = m.plan(&h);
        let cpu = CpuTileExecutor::default();
        let pjrt = PjrtGatherExecutor::new();
        let a = cpu.execute(&h, &head_plan);
        let b = pjrt.execute(&h, &head_plan);
        ensure(
            a.out.data == b.out.data,
            format!("{}: pjrt backend not bitwise-equal on flat K/V", m.name()),
        )?;
        ensure(a.cost == b.cost, format!("{}: pjrt cost differs", m.name()))?;

        // Paged route: same rows behind a reversed page table.
        let page_tokens = 16;
        let n_pages = c.n.div_ceil(page_tokens);
        let mut store = PagedKvStore::new(n_pages, page_tokens, c.d);
        let pages: Vec<u32> = (0..n_pages as u32).rev().collect();
        for pos in 0..c.n {
            store
                .write(&pages, pos, h.k.row(pos), h.v.row(pos))
                .map_err(|e| e.to_string())?;
        }
        for backend in [&cpu as &dyn Executor, &pjrt as &dyn Executor] {
            let paged = PagedExecutor::new(&store, &pages, backend)
                .try_execute(&h.q, &head_plan)
                .map_err(|e| e.to_string())?;
            ensure(
                a.out.data == paged.out.data,
                format!("{}: {} paged route not bitwise-equal", m.name(), backend.name()),
            )?;
            ensure(
                a.cost == paged.cost,
                format!("{}: {} paged cost differs", m.name(), backend.name()),
            )?;
        }
        Ok(())
    });
}

/// Backend parity, batched: for all six methods the PJRT backend matches
/// the CPU backend bitwise on the sequential batched path, the cached
/// path, and the pipelined path (hit accounting included).
#[test]
fn pjrt_backend_matches_cpu_sequential_and_pipelined_for_all_six_methods() {
    let mut rng = Pcg64::seeded(0xB4C7);
    let heads: Vec<HeadInput> = (0..4).map(|_| rand_head(&mut rng, 128, 8)).collect();
    let batch = BatchInput::new(heads);
    let keys = vec![
        PlanKey::new(0, 0),
        PlanKey::new(0, 0),
        PlanKey::new(0, 1),
        PlanKey::new(0, 1),
    ];
    for method_idx in 0..6 {
        let c = ParityCase { seed: 5, n: 128, d: 8, method_idx, theta: 3.0, step: 2 };
        let m = method_for(&c);

        let seq_cpu = uncached_session(&m, ExecutorKind::Cpu, false).run_batch(&batch).unwrap();
        let seq_pjrt = uncached_session(&m, ExecutorKind::Pjrt, false).run_batch(&batch).unwrap();
        let piped_pjrt = uncached_session(&m, ExecutorKind::Pjrt, true)
            .run_batch(&batch)
            .unwrap_or_else(|e| panic!("{}: pjrt pipelined run failed: {e}", m.name()));
        for (h, a) in seq_cpu.outputs.iter().enumerate() {
            assert_eq!(
                a.out.data, seq_pjrt.outputs[h].out.data,
                "{} head {h}: pjrt sequential differs from cpu",
                m.name()
            );
            assert_eq!(a.cost, seq_pjrt.outputs[h].cost, "{} head {h}: cost", m.name());
            assert_eq!(
                a.out.data, piped_pjrt.outputs[h].out.data,
                "{} head {h}: pjrt pipelined differs from cpu sequential",
                m.name()
            );
            assert_eq!(a.cost, piped_pjrt.outputs[h].cost, "{} head {h}", m.name());
        }

        let mut cpu_session = m.session().keys(keys.clone()).build().unwrap();
        let mut pjrt_session = m
            .session()
            .keys(keys.clone())
            .executor(ExecutorKind::Pjrt)
            .pipelined(true)
            .build()
            .unwrap();
        let cached_cpu = cpu_session.run_batch(&batch).unwrap();
        let cached_pjrt = pjrt_session
            .run_batch(&batch)
            .unwrap_or_else(|e| panic!("{}: cached pjrt pipelined failed: {e}", m.name()));
        assert_eq!(
            (cached_cpu.cache_hits, cached_cpu.cache_misses),
            (cached_pjrt.cache_hits, cached_pjrt.cache_misses),
            "{}: hit accounting differs across backends",
            m.name()
        );
        for (h, a) in cached_cpu.outputs.iter().enumerate() {
            assert_eq!(
                a.out.data, cached_pjrt.outputs[h].out.data,
                "{} head {h}: cached pjrt pipelined differs",
                m.name()
            );
            assert_eq!(a.cost, cached_pjrt.outputs[h].cost, "{} head {h}", m.name());
        }
    }
}

/// Plan coverage is exactly the executed coverage for every method (the
/// metrics pipeline may skip execution entirely).
#[test]
fn prop_plan_coverage_equals_executed_coverage() {
    let cfg = Config::heavy(18, 0xC0FE);
    check(&cfg, gen_case, shrink_case, |c| {
        let mut rng = Pcg64::seeded(c.seed);
        let h = rand_head(&mut rng, c.n, c.d);
        let m = method_for(c);
        let head_plan = m.plan(&h);
        let out = uncached_session(&m, ExecutorKind::Cpu, false)
            .run(&h)
            .map_err(|e| e.to_string())?
            .into_single();
        let a = head_plan.coverage();
        let b = &out.coverage;
        ensure(
            a.total_covered() == b.total_covered() && a.sparsity() == b.sparsity(),
            format!("{}: plan coverage != executed coverage", m.name()),
        )
    });
}

/// Run-length span lowering is bitwise-equal to plain per-coordinate
/// lowering for every planner, across every execution route: direct
/// cpu/pjrt executors, sequential and pipelined sessions, flat and paged
/// K/V. Runs only change the read width of the K'/V' assembly, never the
/// folded values.
#[test]
fn prop_run_lowering_matches_discrete_everywhere() {
    let cfg = Config::heavy(16, 0x57121BE5);
    check(&cfg, gen_case, shrink_case, |c| {
        let mut rng = Pcg64::seeded(c.seed);
        let h = rand_head(&mut rng, c.n, c.d);
        let m = method_for(c);
        let plan = m.plan(&h);

        let discrete =
            CpuTileExecutor { lowering: LoweringMode::Discrete, ..Default::default() };
        let runs = CpuTileExecutor::default();
        let reference = discrete.execute(&h, &plan);
        let fast = runs.execute(&h, &plan);
        ensure(
            reference.out.data == fast.out.data,
            format!("{}: runs differ from discrete (flat cpu)", m.name()),
        )?;
        ensure(
            reference.cost == fast.cost,
            format!("{}: cost differs between lowering modes", m.name()),
        )?;

        let pjrt = PjrtGatherExecutor::new().execute(&h, &plan);
        ensure(
            reference.out.data == pjrt.out.data,
            format!("{}: pjrt differs from the discrete reference", m.name()),
        )?;

        // Paged route: both lowering modes over paged memory.
        let page_tokens = 16;
        let n_pages = c.n.div_ceil(page_tokens);
        let mut store = PagedKvStore::new(n_pages, page_tokens, c.d);
        let pages: Vec<u32> = (0..n_pages as u32).rev().collect();
        for pos in 0..c.n {
            store
                .write(&pages, pos, h.k.row(pos), h.v.row(pos))
                .map_err(|e| e.to_string())?;
        }
        for inner in [&runs, &discrete] {
            let paged = PagedExecutor::new(&store, &pages, inner)
                .try_execute(&h.q, &plan)
                .map_err(|e| e.to_string())?;
            ensure(
                reference.out.data == paged.out.data,
                format!("{}: paged route differs from the discrete reference", m.name()),
            )?;
        }

        // Session dispatch (runs lowering internally): sequential and
        // pipelined on both backends.
        for kind in [ExecutorKind::Cpu, ExecutorKind::Pjrt] {
            for pipelined in [false, true] {
                let s = uncached_session(&m, kind, pipelined)
                    .run(&h)
                    .map_err(|e| e.to_string())?;
                ensure(
                    reference.out.data == s.outputs[0].out.data,
                    format!(
                        "{} ({}, pipelined={pipelined}): session differs from the \
                         discrete reference",
                        m.name(),
                        kind.name()
                    ),
                )?;
            }
        }
        Ok(())
    });
}

/// The redesign's acceptance bar, kept after the shims' removal: every
/// session path — sequential/pipelined × cpu/pjrt, uncached and cached —
/// is bitwise-identical to the sequential CPU reference for every method,
/// and the per-head output matches the paged-KV route.
#[test]
fn session_paths_agree_for_all_six_methods() {
    let mut rng = Pcg64::seeded(0x5E55);
    let heads: Vec<HeadInput> = (0..4).map(|_| rand_head(&mut rng, 128, 8)).collect();
    let batch = BatchInput::new(heads.clone());
    let keys = vec![
        PlanKey::new(0, 0),
        PlanKey::new(0, 0),
        PlanKey::new(0, 1),
        PlanKey::new(0, 1),
    ];
    for method_idx in 0..6 {
        let c = ParityCase { seed: 9, n: 128, d: 8, method_idx, theta: 3.0, step: 2 };
        let m = method_for(&c);

        // Per-head reference: the sequential CPU session, compared across
        // backends and against the paged route.
        let ref_single =
            uncached_session(&m, ExecutorKind::Cpu, false).run(&heads[0]).unwrap();
        for kind in [ExecutorKind::Cpu, ExecutorKind::Pjrt] {
            let s = uncached_session(&m, kind, false).run(&heads[0]).unwrap();
            assert_eq!(
                ref_single.outputs[0].out.data,
                s.outputs[0].out.data,
                "{} ({}): session.run differs from the CPU reference",
                m.name(),
                kind.name()
            );
            assert_eq!(ref_single.outputs[0].cost, s.outputs[0].cost, "{}", m.name());
        }
        let head_plan = m.plan(&heads[0]);
        let page_tokens = 16;
        let n_pages = 128usize.div_ceil(page_tokens);
        let mut store = PagedKvStore::new(n_pages, page_tokens, 8);
        let pages: Vec<u32> = (0..n_pages as u32).rev().collect();
        for pos in 0..128 {
            store.write(&pages, pos, heads[0].k.row(pos), heads[0].v.row(pos)).unwrap();
        }
        let cpu = CpuTileExecutor::default();
        let paged = PagedExecutor::new(&store, &pages, &cpu)
            .try_execute(&heads[0].q, &head_plan)
            .unwrap();
        assert_eq!(
            ref_single.outputs[0].out.data,
            paged.out.data,
            "{}: paged route differs from the CPU reference",
            m.name()
        );

        // Batched: every dispatch variant vs the sequential CPU batch.
        let ref_batch =
            uncached_session(&m, ExecutorKind::Cpu, false).run_batch(&batch).unwrap();
        let mut ref_cached_session =
            m.session().keys(keys.clone()).executor(ExecutorKind::Cpu).build().unwrap();
        let ref_cached = ref_cached_session.run_batch(&batch).unwrap();
        assert_eq!(
            (ref_cached.cache_hits, ref_cached.cache_misses),
            (2, 2),
            "{}: two distinct keys over four heads",
            m.name()
        );
        for kind in [ExecutorKind::Cpu, ExecutorKind::Pjrt] {
            for pipelined in [false, true] {
                let s = uncached_session(&m, kind, pipelined).run_batch(&batch).unwrap();
                for (h, a) in ref_batch.outputs.iter().enumerate() {
                    assert_eq!(
                        a.out.data,
                        s.outputs[h].out.data,
                        "{} ({}, pipelined={pipelined}) head {h}: batch differs",
                        m.name(),
                        kind.name()
                    );
                    assert_eq!(a.cost, s.outputs[h].cost, "{} head {h}", m.name());
                }
            }
            let mut cached = m.session().keys(keys.clone()).executor(kind).build().unwrap();
            let s = cached.run_batch(&batch).unwrap();
            assert_eq!(
                (ref_cached.cache_hits, ref_cached.cache_misses),
                (s.cache_hits, s.cache_misses),
                "{} ({}): cached accounting differs",
                m.name(),
                kind.name()
            );
            for (h, a) in ref_cached.outputs.iter().enumerate() {
                assert_eq!(a.out.data, s.outputs[h].out.data, "{} head {h}", m.name());
                assert_eq!(a.cost, s.outputs[h].cost, "{} head {h}", m.name());
            }
        }
    }
}

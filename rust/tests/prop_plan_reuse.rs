//! Speculative plan reuse wall (DESIGN.md §17):
//!
//! * **Exact is inert** — a session built with `reuse(Exact)` is
//!   bitwise-identical to one built without the knob, for all six
//!   planners × cpu/pjrt × unsharded/sharded: outputs, plans, costs and
//!   hit accounting. The reuse layer must be invisible until asked for.
//! * **Speculation never changes output** — an accepted cross-layer or
//!   prefix donor yields outputs bitwise-equal to fresh identification at
//!   strictly lower paid identification cost; a *wrong* donor always
//!   fails the recall check and falls back to coordinates identical to
//!   fresh identification (speed can degrade, correctness cannot).
//! * **Property form** — randomized shapes/params via the in-tree
//!   proptest harness, same generator style as `prop_shard_parity.rs`.

use std::sync::Arc;

use anchor_attention::attention::anchor::AnchorConfig;
use anchor_attention::attention::baselines::block_topk::BlockTopKConfig;
use anchor_attention::attention::baselines::flexprefill::FlexPrefillConfig;
use anchor_attention::attention::baselines::streaming::StreamingConfig;
use anchor_attention::attention::baselines::vertical_slash::VerticalSlashConfig;
use anchor_attention::attention::exec::ExecutorKind;
use anchor_attention::attention::plan::{BatchInput, PlanCache, PlanKey};
use anchor_attention::attention::reuse::ReusePolicy;
use anchor_attention::attention::session::SessionOutput;
use anchor_attention::attention::{HeadInput, Method, TileConfig};
use anchor_attention::tensor::Mat;
use anchor_attention::util::proptest::{check, choose, ensure, Config};
use anchor_attention::util::rng::Pcg64;

fn rand_head(rng: &mut Pcg64, n: usize, d: usize) -> HeadInput {
    HeadInput::new(
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
    )
}

fn anchor_cfg() -> AnchorConfig {
    AnchorConfig {
        tile: TileConfig::new(16, 16),
        theta: 3.0,
        step: 2,
        init_blocks: 1,
        use_anchor: true,
    }
}

fn method_for(idx: usize) -> Method {
    let tile = TileConfig::new(16, 16);
    match idx {
        0 => Method::Full(tile),
        1 => Method::Anchor(anchor_cfg()),
        2 => Method::Streaming(StreamingConfig { tile, global_tokens: 16, local_tokens: 32 }),
        3 => Method::VerticalSlash(VerticalSlashConfig {
            tile,
            vertical_tokens: 8,
            slash_tokens: 8,
            last_q: 16,
        }),
        4 => Method::FlexPrefill(FlexPrefillConfig { tile, gamma: 0.85, min_budget_tokens: 16 }),
        _ => Method::BlockTopK(BlockTopKConfig { tile, k: 3, force_sink_local: true }),
    }
}

fn assert_bitwise(tag: &str, a: &SessionOutput, b: &SessionOutput) {
    assert_eq!(a.outputs.len(), b.outputs.len(), "{tag}: head count");
    for (h, (x, y)) in a.outputs.iter().zip(&b.outputs).enumerate() {
        assert_eq!(x.out.data, y.out.data, "{tag} head {h}: output not bitwise-equal");
        assert_eq!(x.cost, y.cost, "{tag} head {h}: cost differs");
    }
    for (h, (p, q)) in a.plans.iter().zip(&b.plans).enumerate() {
        assert_eq!(**p, **q, "{tag} head {h}: plan differs");
    }
    assert_eq!(
        (a.cache_hits, a.cache_misses),
        (b.cache_hits, b.cache_misses),
        "{tag}: hit accounting differs"
    );
    assert_eq!(a.ident_cost_paid, b.ident_cost_paid, "{tag}: ident attribution differs");
}

/// `reuse(Exact)` is the do-nothing policy: bitwise-identical sessions
/// for all six planners, both executors, unsharded and sharded, cold and
/// warm — and it reports zero speculative activity.
#[test]
fn exact_reuse_is_bitwise_inert_for_all_six_methods() {
    let mut rng = Pcg64::seeded(0x2E05E);
    let heads: Vec<HeadInput> = (0..4).map(|_| rand_head(&mut rng, 96, 8)).collect();
    let batch = BatchInput::new(heads);
    let keys = vec![
        PlanKey::new(0, 0),
        PlanKey::new(0, 0),
        PlanKey::new(0, 1),
        PlanKey::new(1, 0),
    ];
    for idx in 0..6 {
        let m = method_for(idx);
        for kind in [ExecutorKind::Cpu, ExecutorKind::Pjrt] {
            let tag = format!("{} ({})", m.name(), kind.name());
            let mut plain = m.session().keys(keys.clone()).executor(kind).build().unwrap();
            let mut exact = m
                .session()
                .keys(keys.clone())
                .executor(kind)
                .reuse(ReusePolicy::Exact)
                .build()
                .unwrap();
            for round in 0..2 {
                let a = plain.run_batch(&batch).unwrap();
                let b = exact.run_batch(&batch).unwrap();
                assert_bitwise(&format!("{tag} round {round}"), &a, &b);
                assert_eq!(
                    (b.speculative_hits, b.speculative_fallbacks, b.speculative_recall),
                    (0, 0, None),
                    "{tag}: exact must never speculate"
                );
            }
            // Sharded: the same knob through the sharded builder.
            let mut sh = m
                .sharded_session(2)
                .keys(keys.clone())
                .executor(kind)
                .reuse(ReusePolicy::Exact)
                .build()
                .unwrap();
            let merged = sh.run_batch(&batch).unwrap();
            let base = m
                .session()
                .keys(keys.clone())
                .executor(kind)
                .build()
                .unwrap()
                .run_batch(&batch)
                .unwrap();
            assert_bitwise(&format!("{tag} sharded"), &base, &merged);
        }
    }
}

/// Non-exact reuse is anchor-only: every other planner rejects it at
/// build time (both builders), never silently ignoring the knob.
#[test]
fn non_anchor_methods_reject_speculative_reuse_at_build() {
    for idx in [0usize, 2, 3, 4, 5] {
        let m = method_for(idx);
        for policy in [ReusePolicy::cross_layer(), ReusePolicy::prefix()] {
            let err = m.session().reuse(policy).build().map(|_| ()).unwrap_err().to_string();
            assert!(err.contains("anchor"), "{}: {err}", m.name());
            let err =
                m.sharded_session(2).reuse(policy).build().map(|_| ()).unwrap_err().to_string();
            assert!(err.contains("anchor"), "{} sharded: {err}", m.name());
        }
    }
}

/// A cross-layer donor from an identical input is accepted at recall 1.0
/// and serves bitwise-identical output at strictly lower paid
/// identification cost; the same holds through the sharded session
/// (thread workers speculating against the shared cache).
#[test]
fn accepted_cross_layer_donor_is_bitwise_equal_and_cheaper() {
    let m = Method::Anchor(anchor_cfg());
    let mut rng = Pcg64::seeded(0xC105);
    let head = rand_head(&mut rng, 256, 8);
    let batch = BatchInput::new(vec![head.clone()]);
    let keys = vec![PlanKey::new(1, 0)];
    let donor = Arc::new(m.plan(&head));

    let exact = m
        .session()
        .keys(keys.clone())
        .build()
        .unwrap()
        .run_batch(&batch)
        .unwrap();

    let seeded = PlanCache::new();
    seeded.seed(PlanKey::new(0, 0), donor.clone());
    let spec = m
        .session()
        .keys(keys.clone())
        .cache(seeded)
        .reuse(ReusePolicy::cross_layer())
        .build()
        .unwrap()
        .run_batch(&batch)
        .unwrap();

    assert_eq!((spec.speculative_hits, spec.speculative_fallbacks), (1, 0));
    assert_eq!(spec.speculative_recall, Some(1.0));
    assert_eq!(spec.outputs[0].out.data, exact.outputs[0].out.data);
    assert_eq!(spec.outputs[0].cost, exact.outputs[0].cost);
    assert_eq!(spec.plans[0].groups, exact.plans[0].groups);
    assert!(
        spec.ident_cost_paid.ident_scores < exact.ident_cost_paid.ident_scores,
        "speculative {} !< fresh {}",
        spec.ident_cost_paid.ident_scores,
        exact.ident_cost_paid.ident_scores
    );

    // Sharded form: shared cache pre-seeded with the donor, merged
    // output bitwise-equal and speculative accounting surfaced.
    let shared = Arc::new(PlanCache::new());
    shared.seed(PlanKey::new(0, 0), donor);
    let merged = m
        .sharded_session(2)
        .keys(keys)
        .shared_cache(shared)
        .reuse(ReusePolicy::cross_layer())
        .build()
        .unwrap()
        .run_batch(&batch)
        .unwrap();
    assert_eq!((merged.speculative_hits, merged.speculative_fallbacks), (1, 0));
    assert_eq!(merged.speculative_recall, Some(1.0));
    assert_eq!(merged.outputs[0].out.data, exact.outputs[0].out.data);
    assert!(merged.ident_cost_paid.ident_scores < exact.ident_cost_paid.ident_scores);
}

/// A wrong donor always fails the recall check: output and plan
/// coordinates are bitwise-identical to the exact session's —
/// speculation degraded speed, not correctness. Deterministic by
/// construction: `theta = ∞` makes fresh identification select *every*
/// candidate column, so an empty-stripe donor scores recall exactly 0.
#[test]
fn wrong_donor_always_falls_back_without_changing_output() {
    let cfg = AnchorConfig { theta: f32::INFINITY, ..anchor_cfg() };
    let m = Method::Anchor(cfg);
    let mut rng = Pcg64::seeded(0xBAD0);
    let head = rand_head(&mut rng, 256, 8);
    let batch = BatchInput::new(vec![head.clone()]);
    let keys = vec![PlanKey::new(1, 0)];

    let fresh = m.plan(&head);
    assert!(fresh.groups.iter().any(|g| !g.stripes.is_empty()), "needs a non-trivial plan");
    let mut wrong = fresh.clone();
    for grp in wrong.groups.iter_mut() {
        grp.stripes.clear();
    }

    let exact = m
        .session()
        .keys(keys.clone())
        .build()
        .unwrap()
        .run_batch(&batch)
        .unwrap();

    let seeded = PlanCache::new();
    seeded.seed(PlanKey::new(0, 0), Arc::new(wrong));
    let spec = m
        .session()
        .keys(keys)
        .cache(seeded)
        .reuse(ReusePolicy::cross_layer().with_recall_floor(0.99))
        .build()
        .unwrap()
        .run_batch(&batch)
        .unwrap();

    assert_eq!((spec.speculative_hits, spec.speculative_fallbacks), (0, 1));
    assert_eq!(spec.speculative_recall, Some(0.0));
    assert_eq!(spec.outputs[0].out.data, exact.outputs[0].out.data);
    assert_eq!(spec.plans[0].groups, exact.plans[0].groups);
    // The wasted check is charged: fallback pays more than plain fresh.
    assert!(
        spec.ident_cost_paid.ident_scores > exact.ident_cost_paid.ident_scores,
        "fallback {} !> fresh {}",
        spec.ident_cost_paid.ident_scores,
        exact.ident_cost_paid.ident_scores
    );
}

/// A donor of the wrong length is structurally invisible to cross-layer
/// lookup: a plain miss with zero speculative activity, output unchanged.
#[test]
fn wrong_length_donor_is_a_plain_miss() {
    let m = Method::Anchor(anchor_cfg());
    let mut rng = Pcg64::seeded(0x1E4);
    let short = rand_head(&mut rng, 128, 8);
    let head = rand_head(&mut rng, 256, 8);
    let batch = BatchInput::new(vec![head.clone()]);

    let exact = m
        .session()
        .keys(vec![PlanKey::new(1, 0)])
        .build()
        .unwrap()
        .run_batch(&batch)
        .unwrap();

    let seeded = PlanCache::new();
    seeded.seed(PlanKey::new(0, 0), Arc::new(m.plan(&short)));
    let spec = m
        .session()
        .keys(vec![PlanKey::new(1, 0)])
        .cache(seeded)
        .reuse(ReusePolicy::cross_layer())
        .build()
        .unwrap()
        .run_batch(&batch)
        .unwrap();
    assert_eq!((spec.speculative_hits, spec.speculative_fallbacks), (0, 0));
    assert_eq!(spec.speculative_recall, None);
    assert_bitwise("wrong-length donor", &exact, &spec);
}

/// Prefix reuse across a length change in a multi-head GQA batch: the
/// grown batch reports speculative hits, pays less identification than a
/// cold exact session at the new length, and stays bitwise-equal to it.
#[test]
fn prefix_reuse_extends_a_grown_batch_bitwise() {
    let m = Method::Anchor(anchor_cfg());
    let mut rng = Pcg64::seeded(0x9EF1);
    let n_full = 256;
    let n_prefix = 128;
    let shared = rand_head(&mut rng, n_full, 8);
    let mut other_v = shared.clone();
    for x in other_v.v.data.iter_mut() {
        *x += 0.5;
    }
    // Two heads, one key (GQA group): same Q/K, different V.
    let full_batch = BatchInput::new(vec![shared.clone(), other_v.clone()]);
    let prefix_of = |h: &HeadInput| {
        HeadInput::new(
            h.q.rows_mat(0, n_prefix),
            h.k.rows_mat(0, n_prefix),
            h.v.rows_mat(0, n_prefix),
        )
    };
    let prefix_batch = BatchInput::new(vec![prefix_of(&shared), prefix_of(&other_v)]);
    let keys = vec![PlanKey::new(0, 0), PlanKey::new(0, 0)];

    let mut session = m
        .session()
        .keys(keys.clone())
        .reuse(ReusePolicy::prefix())
        .build()
        .unwrap();
    let short = session.run_batch(&prefix_batch).unwrap();
    assert_eq!(short.speculative_hits, 0, "no donors before the length change");
    let grown = session.run_batch(&full_batch).unwrap();
    assert_eq!((grown.cache_hits, grown.cache_misses), (1, 1));
    assert_eq!((grown.speculative_hits, grown.speculative_fallbacks), (1, 0));

    let exact = m
        .session()
        .keys(keys)
        .build()
        .unwrap()
        .run_batch(&full_batch)
        .unwrap();
    for (h, (a, b)) in grown.outputs.iter().zip(&exact.outputs).enumerate() {
        assert_eq!(a.out.data, b.out.data, "head {h}");
    }
    assert!(
        grown.ident_cost_paid.ident_scores < exact.ident_cost_paid.ident_scores,
        "prefix extension {} !< cold {}",
        grown.ident_cost_paid.ident_scores,
        exact.ident_cost_paid.ident_scores
    );
}

/// Property form: over random shapes and anchor params, (1) exact reuse
/// is bitwise-inert, and (2) an identical-input cross-layer donor either
/// hits at recall 1.0 with bitwise-equal output and cheaper ident, or —
/// when the plan has nothing checkable — is at worst output-neutral.
#[test]
fn prop_speculation_is_output_neutral() {
    #[derive(Clone, Debug)]
    struct Case {
        seed: u64,
        n: usize,
        d: usize,
        theta: f32,
        step: usize,
    }
    let cfg = Config::heavy(16, 0x5EC5);
    check(
        &cfg,
        |rng| Case {
            seed: rng.next_u64(),
            n: *choose(rng, &[64, 128, 192, 256]),
            d: *choose(rng, &[8, 16]),
            theta: *choose(rng, &[-2.0, 0.5, 3.0, 8.0]),
            step: *choose(rng, &[1, 2, 4]),
        },
        |_| Vec::new(),
        |c| {
            let m = Method::Anchor(AnchorConfig {
                tile: TileConfig::new(16, 16),
                theta: c.theta,
                step: c.step,
                init_blocks: 1,
                use_anchor: true,
            });
            let mut rng = Pcg64::seeded(c.seed);
            let head = rand_head(&mut rng, c.n, c.d);
            let batch = BatchInput::new(vec![head.clone()]);

            let exact = m
                .session()
                .keys(vec![PlanKey::new(1, 0)])
                .build()
                .map_err(|e| e.to_string())?
                .run_batch(&batch)
                .map_err(|e| e.to_string())?;
            let inert = m
                .session()
                .keys(vec![PlanKey::new(1, 0)])
                .reuse(ReusePolicy::Exact)
                .build()
                .map_err(|e| e.to_string())?
                .run_batch(&batch)
                .map_err(|e| e.to_string())?;
            ensure(
                inert.outputs[0].out.data == exact.outputs[0].out.data
                    && inert.ident_cost_paid == exact.ident_cost_paid,
                "exact reuse is not inert".to_string(),
            )?;

            let seeded = PlanCache::new();
            seeded.seed(PlanKey::new(0, 0), Arc::new(m.plan(&head)));
            let spec = m
                .session()
                .keys(vec![PlanKey::new(1, 0)])
                .cache(seeded)
                .reuse(ReusePolicy::cross_layer())
                .build()
                .map_err(|e| e.to_string())?
                .run_batch(&batch)
                .map_err(|e| e.to_string())?;
            ensure(
                spec.outputs[0].out.data == exact.outputs[0].out.data,
                "speculation changed the output".to_string(),
            )?;
            ensure(
                spec.speculative_fallbacks == 0,
                "an identical-input donor must never fail the check".to_string(),
            )?;
            if spec.speculative_hits > 0 {
                ensure(
                    spec.speculative_recall == Some(1.0),
                    format!("identical donor recall {:?}", spec.speculative_recall),
                )?;
                ensure(
                    spec.ident_cost_paid.ident_scores <= exact.ident_cost_paid.ident_scores,
                    "accepted donor paid more than fresh identification".to_string(),
                )?;
            }
            Ok(())
        },
    );
}

//! Plan-persistence properties (DESIGN.md §11):
//!
//! * **Round trip** — arbitrary valid `SparsePlan` → manifest JSON →
//!   `SparsePlan` is the identity, `predicted_cost` included (it is
//!   re-derived from the coordinates, and the derivation is
//!   deterministic).
//! * **Corruption is loud** — a corrupted or truncated store entry is
//!   rejected with an error at `PlanStore::open`, never a silent empty
//!   plan.
//! * **Restart warm-start** — a process "restarted" against a populated
//!   store (fresh session, same manifest path) reports a plan-cache hit
//!   on the first `run_batch` for a previously seen
//!   `(model, layer, head_group, n)` key, pays zero identification, and
//!   produces bitwise-identical output.
//! * **Concurrent stores never lose entries** — shard coordinators and
//!   parallel sessions each open their own `PlanStore` on one manifest;
//!   interleaved insert/flush/warm across threads must end with every
//!   thread's entries on disk (flush merges under the per-path lock,
//!   DESIGN.md §12) and the manifest intact.

use std::path::PathBuf;
use std::sync::Arc;

use anchor_attention::attention::anchor::AnchorConfig;
use anchor_attention::attention::plan::{BatchInput, GroupPlan, PlanKey, SparsePlan};
use anchor_attention::attention::{CostTally, HeadInput, Method, TileConfig};
use anchor_attention::runtime::manifest::{plan_from_json, plan_to_json, PlanStore, PlanStoreKey};
use anchor_attention::util::json::Json;
use anchor_attention::util::proptest::{check, choose, ensure, Config};
use anchor_attention::util::rng::Pcg64;

fn tmp_manifest(tag: &str) -> PathBuf {
    let path = std::env::temp_dir()
        .join(format!("anchor_plan_store_{}_{tag}.json", std::process::id()));
    std::fs::write(&path, "{}\n").unwrap();
    path
}

/// An arbitrary structurally-valid plan: random shape, random sorted
/// disjoint spans, random ascending stripes, random ident provenance.
fn rand_plan(rng: &mut Pcg64) -> (SparsePlan, usize) {
    let b_q = *choose(rng, &[8usize, 16, 32]);
    let b_kv = *choose(rng, &[8usize, 16]);
    let n = *choose(rng, &[64usize, 100, 128, 160]);
    let d = *choose(rng, &[4usize, 8, 16]);
    let step = *choose(rng, &[1usize, 2, 3]);
    let tile = TileConfig::new(b_q, b_kv);
    let n_groups = tile.q_blocks(n).div_ceil(step);
    let mut groups = Vec::with_capacity(n_groups);
    for _ in 0..n_groups {
        let mut spans = Vec::new();
        let mut cursor = 0usize;
        while cursor + 2 < n && rng.next_below(2) == 0 {
            let s = cursor + rng.next_below((n - cursor - 2) as u64) as usize;
            let e = (s + 1 + rng.next_below(16) as usize).min(n);
            spans.push((s as u32, e as u32));
            cursor = e + 1;
        }
        let mut stripes = Vec::new();
        let mut col = rng.next_below(8) as usize;
        while col < n && stripes.len() < 24 {
            stripes.push(col as u32);
            col += 1 + rng.next_below(9) as usize;
        }
        groups.push(GroupPlan { spans, stripes });
    }
    let ident = CostTally {
        flops: rng.next_below(1_000_000),
        kv_bytes: rng.next_below(1_000_000),
        ident_scores: rng.next_below(1_000_000),
    };
    let method = *choose(
        rng,
        &["full-attn", "anchor", "streaming-llm", "vertical-slash", "flexprefill", "block-topk"],
    );
    (SparsePlan::new(method, n, d, tile, step, groups, ident), d)
}

#[test]
fn prop_plan_json_round_trip_is_identity() {
    let cfg = Config::heavy(32, 0x51073);
    check(
        &cfg,
        |rng| rng.next_u64(),
        |_| vec![],
        |&seed| {
            let mut rng = Pcg64::seeded(seed);
            let (plan, d) = rand_plan(&mut rng);
            let text = plan_to_json(&plan, d).to_string();
            let reparsed = Json::parse(&text).map_err(|e| e.to_string())?;
            let (back, d_back) = plan_from_json(&reparsed).map_err(|e| e.to_string())?;
            ensure(d_back == d, "head dim changed in round trip")?;
            ensure(back == plan, "plan -> json -> plan is not the identity")
        },
    );
}

#[test]
fn prop_store_file_round_trip_is_identity() {
    let cfg = Config::heavy(8, 0x51074);
    check(
        &cfg,
        |rng| rng.next_u64(),
        |_| vec![],
        |&seed| {
            let mut rng = Pcg64::seeded(seed);
            let path = tmp_manifest(&format!("prop_{seed:x}"));
            let (plan, d) = rand_plan(&mut rng);
            let key = PlanStoreKey {
                model: format!("m{}", rng.next_below(3)),
                layer: rng.next_below(4) as u32,
                head_group: rng.next_below(4) as u32,
                n: plan.n,
            };
            let mut store = PlanStore::open(&path).map_err(|e| e.to_string())?;
            store.insert(key.clone(), d, Arc::new(plan.clone()));
            store.flush().map_err(|e| e.to_string())?;
            let reopened = PlanStore::open(&path).map_err(|e| e.to_string())?;
            let got = reopened.get(&key).ok_or("stored plan vanished")?;
            let _ = std::fs::remove_file(&path);
            ensure(*got == plan, "store file round trip is not the identity")
        },
    );
}

#[test]
fn prop_corrupted_store_is_rejected() {
    // Write one good entry, then corrupt the serialized text at an
    // arbitrary structural point: open must fail, never succeed with a
    // silently empty (or altered) store.
    let path = tmp_manifest("corruption_sweep");
    let mut rng = Pcg64::seeded(0xC0881);
    let (plan, d) = rand_plan(&mut rng);
    let key = PlanStoreKey { model: "m".into(), layer: 1, head_group: 2, n: plan.n };
    let mut store = PlanStore::open(&path).unwrap();
    store.insert(key, d, Arc::new(plan));
    store.flush().unwrap();
    let good = std::fs::read_to_string(&path).unwrap();

    // Truncations at many byte offsets: every prefix must be rejected
    // (either invalid JSON or a structurally incomplete store).
    let ps_start = good.find("\"plan_store\"").unwrap();
    for frac in [0.2, 0.5, 0.8, 0.95] {
        let cut = ps_start + ((good.len() - ps_start) as f64 * frac) as usize;
        std::fs::write(&path, &good[..cut]).unwrap();
        assert!(PlanStore::open(&path).is_err(), "truncation at byte {cut} must be rejected");
    }

    // Field-level corruption.
    for (from, to) in [
        ("\"version\": 1", "\"version\": 2"),
        ("\"entries\": [", "\"entries\": 3, \"x\": ["),
        ("\"groups\": [", "\"groups\": [{\"spans\": [], \"stripes\": []}, "),
    ] {
        assert!(good.contains(from), "fixture drifted: {from}");
        std::fs::write(&path, good.replace(from, to)).unwrap();
        assert!(PlanStore::open(&path).is_err(), "corruption {from} -> {to} accepted");
    }

    std::fs::write(&path, &good).unwrap();
    assert_eq!(PlanStore::open(&path).unwrap().len(), 1, "pristine store must reopen");
    let _ = std::fs::remove_file(&path);
}

/// The contention wall: K writer threads each open their own store on one
/// manifest and interleave inserts with flushes (multiple flushes per
/// thread, so later flushes race earlier ones from other threads), while
/// reader threads concurrently open and warm (`plans_for`). Every entry
/// from every writer must survive on disk — the merge-on-flush under the
/// per-path lock is what prevents last-writer-wins loss — and the
/// manifest's other keys stay intact.
#[test]
fn concurrent_stores_on_one_manifest_never_lose_entries() {
    let path = tmp_manifest("contention");
    std::fs::write(&path, "{\"other_key\": 7}\n").unwrap();
    const WRITERS: usize = 4;
    const ENTRIES_PER_WRITER: usize = 6;
    let mut rng = Pcg64::seeded(0xC0117);
    // One shared plan (contents don't matter; keys carry the identity).
    let plan = {
        let (p, _) = rand_plan(&mut rng);
        Arc::new(p)
    };
    let n = plan.n;
    let d = 8;

    std::thread::scope(|scope| {
        for w in 0..WRITERS {
            let path = path.clone();
            let plan = plan.clone();
            scope.spawn(move || {
                let mut store = PlanStore::open(&path).unwrap();
                for i in 0..ENTRIES_PER_WRITER {
                    store.insert(
                        PlanStoreKey {
                            model: format!("writer-{w}"),
                            layer: 0,
                            head_group: i as u32,
                            n,
                        },
                        d,
                        plan.clone(),
                    );
                    // Flush mid-stream: later flushes from other writers
                    // must merge, not erase, what this one committed.
                    if i % 2 == 1 {
                        store.flush().unwrap();
                    }
                }
                store.flush().unwrap();
            });
        }
        // Readers interleave opens + warm passes; they must only ever see
        // a valid store (rename is atomic) and never poison the writers.
        for r in 0..2 {
            let path = path.clone();
            scope.spawn(move || {
                for _ in 0..8 {
                    let mut store = PlanStore::open(&path).unwrap();
                    let _ = store.plans_for(&format!("writer-{r}"), n);
                    std::thread::yield_now();
                }
            });
        }
    });

    let final_store = PlanStore::open(&path).unwrap();
    assert_eq!(
        final_store.len(),
        WRITERS * ENTRIES_PER_WRITER,
        "interleaved flushes lost entries"
    );
    for w in 0..WRITERS {
        for i in 0..ENTRIES_PER_WRITER {
            let key = PlanStoreKey {
                model: format!("writer-{w}"),
                layer: 0,
                head_group: i as u32,
                n,
            };
            assert!(final_store.get(&key).is_some(), "writer {w} entry {i} vanished");
        }
    }
    // The manifest document outside plan_store survives every rewrite.
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(doc.get("other_key").as_usize(), Some(7));
    let _ = std::fs::remove_file(&path);
}

/// Concurrent sharded sessions persisting to one manifest: the end-to-end
/// form of the contention property. Two sessions with distinct model tags
/// run and drop (flush) in parallel; both tags' plans must be on disk and
/// a restarted session under either tag warm-starts.
#[test]
fn concurrent_sessions_flush_to_one_store_without_loss() {
    let path = tmp_manifest("contention_sessions");
    let m = Method::Anchor(AnchorConfig {
        tile: TileConfig::new(16, 16),
        theta: 4.0,
        step: 2,
        init_blocks: 1,
        use_anchor: true,
    });
    let mk_batch = |seed: u64| {
        let mut rng = Pcg64::seeded(seed);
        BatchInput::new(
            (0..3)
                .map(|_| {
                    HeadInput::new(
                        anchor_attention::tensor::Mat::from_fn(96, 8, |_, _| rng.normal()),
                        anchor_attention::tensor::Mat::from_fn(96, 8, |_, _| rng.normal()),
                        anchor_attention::tensor::Mat::from_fn(96, 8, |_, _| rng.normal()),
                    )
                })
                .collect(),
        )
    };
    std::thread::scope(|scope| {
        for (tag, seed) in [("cell-a", 11u64), ("cell-b", 12u64)] {
            let path = path.clone();
            let m = m.clone();
            scope.spawn(move || {
                let mut session = m
                    .sharded_session(2)
                    .persist(&path)
                    .model(tag)
                    .build()
                    .unwrap();
                session.run_batch(&mk_batch(seed)).unwrap();
                session.flush().unwrap();
            });
        }
    });
    let store = PlanStore::open(&path).unwrap();
    assert_eq!(store.len(), 6, "both sessions' plans must survive");
    // Either tag warm-starts a restarted sharded session.
    let mut warm = m
        .sharded_session(3)
        .persist(&path)
        .model("cell-a")
        .build()
        .unwrap();
    let out = warm.run_batch(&mk_batch(11)).unwrap();
    assert_eq!((out.cache_hits, out.cache_misses), (3, 0));
    assert_eq!(out.ident_cost_paid, CostTally::default());
    drop(warm);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn restarted_process_warm_starts_from_the_store() {
    let path = tmp_manifest("restart_process");
    let mut rng = Pcg64::seeded(0xAB5E);
    let shared = HeadInput::new(
        anchor_attention::tensor::Mat::from_fn(96, 8, |_, _| rng.normal()),
        anchor_attention::tensor::Mat::from_fn(96, 8, |_, _| rng.normal()),
        anchor_attention::tensor::Mat::from_fn(96, 8, |_, _| rng.normal()),
    );
    let batch = BatchInput::new(vec![shared.clone(), shared]);
    let keys = vec![PlanKey::new(3, 7), PlanKey::new(3, 7)];
    let m = Method::Anchor(AnchorConfig {
        tile: TileConfig::new(16, 16),
        theta: 4.0,
        step: 2,
        init_blocks: 1,
        use_anchor: true,
    });

    let cold_out = {
        let mut cold = m
            .session()
            .keys(keys.clone())
            .persist(&path)
            .model("restart-model")
            .build()
            .unwrap();
        let out = cold.run_batch(&batch).unwrap();
        assert!(out.ident_cost_paid.ident_scores > 0, "cold run must identify");
        cold.flush().unwrap();
        out
    };

    // "Restart": a fresh session against the same manifest path.
    let mut warm = m
        .session()
        .keys(keys)
        .persist(&path)
        .model("restart-model")
        .build()
        .unwrap();
    let warm_out = warm.run_batch(&batch).unwrap();
    assert_eq!(
        (warm_out.cache_hits, warm_out.cache_misses),
        (2, 0),
        "previously seen (model, layer, head_group, n) key must hit on the first batch"
    );
    assert_eq!(warm_out.ident_cost_paid, CostTally::default());
    assert!(warm.store_seeded() > 0);
    for (a, b) in cold_out.outputs.iter().zip(&warm_out.outputs) {
        assert_eq!(a.out.data, b.out.data, "warm output must be bitwise-identical");
    }
    let _ = std::fs::remove_file(&path);
}

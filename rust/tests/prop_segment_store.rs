//! Segmented plan-store crash-safety properties (DESIGN.md §15):
//!
//! * **Truncation is loud** — a segment file cut at *any* byte is
//!   rejected at `PlanStore::open` (the index's max entry end bounds the
//!   file length, so no payload read is needed to notice).
//! * **Bit flips never serve a wrong plan** — a single-bit flip anywhere
//!   in a segment file or in the manifest index either fails `open`, or
//!   opens and then every affected read returns `None` loudly; reads
//!   that do succeed are bitwise-identical to what was stored.
//! * **A killed compaction leaves a working store** — leftover temp
//!   files and fully-written-but-uncommitted segments are ignored at
//!   `open` and swept by the next compaction.
//! * **Legacy migration is bitwise and one-time** — a JSON-blob
//!   `plan_store` is imported into segments on first `open`, every plan
//!   compares equal, the `migrated_from` marker persists, and the legacy
//!   layout is never written again.
//! * **Seeding is lazy** — `plans_for_compatible` decodes only the
//!   index-matched byte ranges: damage confined to non-matching entries
//!   is invisible to a compatible seed pass.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anchor_attention::attention::plan::{GroupPlan, PlanKey, SparsePlan};
use anchor_attention::attention::{CostTally, TileConfig};
use anchor_attention::runtime::manifest::{write_legacy_json_store, PlanStore, PlanStoreKey};
use anchor_attention::runtime::segment::{segments_dir, ENTRY_FRAME_BYTES};
use anchor_attention::util::json::Json;
use anchor_attention::util::proptest::{check, choose, ensure, Config};
use anchor_attention::util::rng::Pcg64;

fn tmp_manifest(tag: &str) -> PathBuf {
    let path = std::env::temp_dir()
        .join(format!("anchor_segment_store_{}_{tag}.json", std::process::id()));
    let _ = std::fs::remove_dir_all(segments_dir(&path));
    std::fs::write(&path, "{}\n").unwrap();
    path
}

fn cleanup(path: &Path) {
    let _ = std::fs::remove_file(path);
    let _ = std::fs::remove_dir_all(segments_dir(path));
}

fn key(model: &str, layer: u32, n: usize) -> PlanStoreKey {
    PlanStoreKey { model: model.to_string(), layer, head_group: 0, n }
}

/// A small deterministic plan; `salt` varies stripes and provenance so
/// distinct entries have distinct payload bytes.
fn sample_plan(n: usize, d: usize, salt: u32) -> SparsePlan {
    let tile = TileConfig::new(16, 16);
    let groups: Vec<GroupPlan> = (0..tile.q_blocks(n).div_ceil(2))
        .map(|g| {
            let win = (g * 32) as u32;
            let end = ((g + 1) * 32).min(n) as u32;
            if win == 0 {
                GroupPlan { spans: vec![(0, end)], stripes: vec![] }
            } else {
                GroupPlan {
                    spans: vec![(0, 16), (win, end)],
                    stripes: (16 + salt % 5..win).step_by(5).collect(),
                }
            }
        })
        .collect();
    let ident = CostTally { flops: 100 + salt as u64, kv_bytes: 7, ident_scores: 3 };
    SparsePlan::new("anchor", n, d, tile, 2, groups, ident)
}

/// The dir's single `seg-*.bin` file (panics if there isn't exactly one).
fn only_segment(dir: &Path) -> String {
    let mut segs: Vec<String> = std::fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| e.unwrap().file_name().to_str().map(str::to_string))
        .filter(|n| n.starts_with("seg-") && n.ends_with(".bin"))
        .collect();
    assert_eq!(segs.len(), 1, "expected exactly one segment, got {segs:?}");
    segs.pop().unwrap()
}

/// Seed a store with three distinct entries in one flush (one segment)
/// and return the keys with the plans they must read back as.
fn seed_three(path: &Path) -> Vec<(PlanStoreKey, SparsePlan)> {
    let mut store = PlanStore::open(path).unwrap();
    let mut want = Vec::new();
    for i in 0..3u32 {
        let plan = sample_plan(64, 8, i);
        store.insert(key("m", i, 64), 8, Arc::new(plan.clone()));
        want.push((key("m", i, 64), plan));
    }
    store.flush().unwrap();
    want
}

/// After a corruption: either `open` failed, or every seeded key reads
/// back as `None` (loud drop) or the exact stored plan — never a
/// different plan.
fn assert_none_or_identical(path: &Path, want: &[(PlanStoreKey, SparsePlan)], what: &str) {
    if let Ok(store) = PlanStore::open(path) {
        for (k, plan) in want {
            match store.get(k) {
                None => {}
                Some(got) => assert_eq!(&*got, plan, "{what} served a wrong plan for {k:?}"),
            }
        }
    }
}

#[test]
fn segment_truncated_at_every_byte_is_rejected_at_open() {
    let path = tmp_manifest("trunc");
    let want = seed_three(&path);
    let dir = segments_dir(&path);
    let seg = only_segment(&dir);
    let original = std::fs::read(dir.join(&seg)).unwrap();
    assert!(original.len() > 8, "segment smaller than its header");
    for len in 0..original.len() {
        std::fs::write(dir.join(&seg), &original[..len]).unwrap();
        assert!(
            PlanStore::open(&path).is_err(),
            "segment truncated to {len}/{} bytes opened cleanly",
            original.len()
        );
    }
    // Restoring the bytes restores the store.
    std::fs::write(dir.join(&seg), &original).unwrap();
    let store = PlanStore::open(&path).unwrap();
    for (k, plan) in &want {
        assert_eq!(store.get(k).as_deref(), Some(plan));
    }
    cleanup(&path);
}

#[test]
fn segment_bit_flips_never_serve_a_wrong_plan() {
    let path = tmp_manifest("segflip");
    let want = seed_three(&path);
    let dir = segments_dir(&path);
    let seg = only_segment(&dir);
    let original = std::fs::read(dir.join(&seg)).unwrap();
    for pos in 0..original.len() {
        let mut bytes = original.clone();
        bytes[pos] ^= 0x01;
        std::fs::write(dir.join(&seg), &bytes).unwrap();
        assert_none_or_identical(&path, &want, &format!("segment bit flip at byte {pos}"));
    }
    std::fs::write(dir.join(&seg), &original).unwrap();
    assert_eq!(PlanStore::open(&path).unwrap().len(), 3);
    cleanup(&path);
}

#[test]
fn index_bit_flips_are_rejected_or_isolated() {
    let path = tmp_manifest("idxflip");
    let want = seed_three(&path);
    let good = std::fs::read(&path).unwrap();
    for pos in 0..good.len() {
        let mut bytes = good.clone();
        bytes[pos] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        assert_none_or_identical(&path, &want, &format!("index bit flip at byte {pos}"));
    }
    std::fs::write(&path, &good).unwrap();
    let store = PlanStore::open(&path).unwrap();
    for (k, plan) in &want {
        assert_eq!(store.get(k).as_deref(), Some(plan));
    }
    cleanup(&path);
}

#[test]
fn killed_compaction_leftovers_are_recovered_and_cleaned() {
    let path = tmp_manifest("killcomp");
    // Three flushes → three live segments referenced by the index.
    let mut store = PlanStore::open(&path).unwrap();
    let mut want = Vec::new();
    for i in 0..3u32 {
        let plan = sample_plan(64, 8, i);
        store.insert(key("m", i, 64), 8, Arc::new(plan.clone()));
        store.flush().unwrap();
        want.push((key("m", i, 64), plan));
    }
    drop(store);
    let dir = segments_dir(&path);
    let mut segs: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.unwrap().file_name().to_str().map(str::to_string))
        .collect();
    segs.sort();
    assert!(segs.len() >= 3, "expected one segment per flush, got {segs:?}");
    // Simulate a compactor killed at both of its crash points: (a) after
    // writing its merged segment but before committing the index — a
    // fully-formed unreferenced file; (b) mid-write — a temp file.
    std::fs::copy(dir.join(&segs[0]), dir.join("seg-000999.bin")).unwrap();
    std::fs::write(dir.join("seg-001000.bin.tmp.12345.0"), b"half-written junk").unwrap();

    // Open ignores both leftovers: the committed index is authoritative.
    let mut store = PlanStore::open(&path).unwrap();
    assert_eq!(store.len(), 3);
    for (k, plan) in &want {
        assert_eq!(store.get(k).as_deref(), Some(plan));
    }
    // The next compaction merges the live segments and sweeps the rest.
    let stats = store.compact().unwrap();
    assert_eq!((stats.segments_after, stats.entries), (1, 3));
    assert!(stats.files_removed >= 4, "leftovers survived: {stats:?}");
    drop(store);
    let after: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.unwrap().file_name().to_str().map(str::to_string))
        .collect();
    assert_eq!(after.len(), 1, "compaction left strays: {after:?}");
    let re = PlanStore::open(&path).unwrap();
    for (k, plan) in &want {
        assert_eq!(re.get(k).as_deref(), Some(plan));
    }
    cleanup(&path);
}

#[test]
fn seeding_decodes_only_the_matching_byte_ranges() {
    let path = tmp_manifest("lazy");
    let mut store = PlanStore::open(&path).unwrap();
    let mut hot = Vec::new();
    for i in 0..2u32 {
        let plan = sample_plan(64, 8, i);
        store.insert(key("hot", i, 64), 8, Arc::new(plan.clone()));
        hot.push((key("hot", i, 64), plan));
    }
    for i in 0..6u32 {
        store.insert(key("cold", i, 64), 8, Arc::new(sample_plan(64, 8, 100 + i)));
    }
    store.flush().unwrap();
    drop(store);
    // Corrupt the first payload byte of every cold entry (locations come
    // from the index), leaving hot entries in the same segment intact.
    let dir = segments_dir(&path);
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let mut corrupted = 0;
    for e in doc.get("plan_store").get("entries").as_arr().unwrap() {
        let seg = e.get("segment").as_str().unwrap().to_string();
        for g in e.get("groups").as_arr().unwrap() {
            if g.get("model").as_str() != Some("cold") {
                continue;
            }
            for rec in g.get("keys").as_arr().unwrap() {
                let offset = rec.idx(2).as_f64().unwrap() as u64;
                let at = (offset + ENTRY_FRAME_BYTES) as usize;
                let mut bytes = std::fs::read(dir.join(&seg)).unwrap();
                bytes[at] ^= 0xFF;
                std::fs::write(dir.join(&seg), &bytes).unwrap();
                corrupted += 1;
            }
        }
    }
    assert_eq!(corrupted, 6, "index lost track of the cold entries");
    // Open never scans payloads (header + length only) and compatible
    // seeding decodes only the matched slice, so the damage is invisible
    // to the hot session...
    let mut store = PlanStore::open(&path).unwrap();
    let seeded = store.plans_for_compatible("hot", 64, "anchor", TileConfig::new(16, 16), 2, 8);
    assert_eq!(seeded.len(), hot.len());
    for (pk, plan) in &seeded {
        let want = hot
            .iter()
            .find(|(k, _)| PlanKey::new(k.layer, k.head_group) == *pk)
            .map(|(_, p)| p)
            .expect("seeded an unknown key");
        assert_eq!(&**plan, want, "lazy seeding decoded wrong bytes");
    }
    // ...while touching a damaged entry is a loud None, never a wrong plan.
    assert!(store.get(&key("cold", 0, 64)).is_none());
    cleanup(&path);
}

#[test]
fn prop_legacy_migration_is_bitwise_and_one_time() {
    let cfg = Config::heavy(6, 0xA2C4);
    check(
        &cfg,
        |rng| rng.next_u64(),
        |_| vec![],
        |&seed| {
            let mut rng = Pcg64::seeded(seed);
            let path = tmp_manifest(&format!("mig_{seed:x}"));
            let count = 1 + rng.next_below(6) as usize;
            let mut entries: Vec<(PlanStoreKey, usize, Arc<SparsePlan>)> = Vec::new();
            for i in 0..count {
                let n = *choose(&mut rng, &[64usize, 96, 128]);
                let d = *choose(&mut rng, &[4usize, 8]);
                let plan = sample_plan(n, d, rng.next_below(1000) as u32);
                entries.push((
                    PlanStoreKey {
                        model: format!("m{}", i % 2),
                        layer: i as u32,
                        head_group: 0,
                        n,
                    },
                    d,
                    Arc::new(plan),
                ));
            }
            write_legacy_json_store(&path, &entries).map_err(|e| e.to_string())?;
            let before = std::fs::read_to_string(&path).map_err(|e| e.to_string())?;
            ensure(before.contains("\"plan\""), "legacy fixture lacks inline plans")?;

            // First open migrates; every plan must survive bitwise.
            let store = PlanStore::open(&path).map_err(|e| e.to_string())?;
            ensure(store.len() == entries.len(), "migration changed the entry count")?;
            for (k, _, plan) in &entries {
                ensure(
                    store.get(k).as_deref() == Some(&**plan),
                    "migrated plan differs from the legacy original",
                )?;
            }
            drop(store);
            let after = std::fs::read_to_string(&path).map_err(|e| e.to_string())?;
            let doc = Json::parse(&after).map_err(|e| e.to_string())?;
            let ps = doc.get("plan_store");
            ensure(ps.get("format").as_str() == Some("segments"), "store not segmented")?;
            ensure(
                ps.get("migrated_from").as_str() == Some("json-v1"),
                "migrated_from marker missing",
            )?;
            ensure(!after.contains("\"plan\""), "legacy inline plans written back")?;

            // Second open is a plain segmented open, still bitwise.
            let re = PlanStore::open(&path).map_err(|e| e.to_string())?;
            for (k, _, plan) in &entries {
                ensure(re.get(k).as_deref() == Some(&**plan), "reopen lost an entry")?;
            }
            cleanup(&path);
            Ok(())
        },
    );
}

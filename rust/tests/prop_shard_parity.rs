//! Sharded ≡ unsharded parity wall (DESIGN.md §12):
//!
//! * **Bitwise parity** — for all six planners, a `ShardedSession`'s
//!   merged output is bitwise-equal to the unsharded `AttentionSession` —
//!   outputs, per-head costs, plans, hit/miss accounting, and ident-cost
//!   attribution — across `shards ∈ {1, 2, 3, 8}` (including counts that
//!   do not divide the head or key count), sequential and pipelined
//!   dispatch, and both executor backends.
//! * **Warm parity** — a second batch over the same sessions stays
//!   bitwise-equal with all-hit accounting: the shared plan cache makes
//!   shard routing invisible to amortization.
//! * **Property form** — randomized shapes/params/shard counts via the
//!   same generator style as `prop_plan_parity.rs`.
//! * **Failure is loud** — a shard whose worker panics (here: poisoned by
//!   a wrong-length plan seeded into the shared cache) surfaces as an
//!   `Err` naming the shard instead of crashing or deadlocking the
//!   coordinator.

use std::sync::Arc;

use anchor_attention::attention::anchor::AnchorConfig;
use anchor_attention::attention::baselines::block_topk::BlockTopKConfig;
use anchor_attention::attention::baselines::flexprefill::FlexPrefillConfig;
use anchor_attention::attention::baselines::streaming::StreamingConfig;
use anchor_attention::attention::baselines::vertical_slash::VerticalSlashConfig;
use anchor_attention::attention::exec::ExecutorKind;
use anchor_attention::attention::plan::{BatchInput, PlanCache, PlanKey};
use anchor_attention::attention::session::{AttentionSession, SessionOutput};
use anchor_attention::attention::shard::ShardedSession;
use anchor_attention::attention::{HeadInput, Method, TileConfig};
use anchor_attention::tensor::Mat;
use anchor_attention::util::proptest::{check, choose, ensure, Config};
use anchor_attention::util::rng::Pcg64;

fn rand_head(rng: &mut Pcg64, n: usize, d: usize) -> HeadInput {
    HeadInput::new(
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
    )
}

fn method_for(idx: usize, theta: f32, step: usize) -> Method {
    let tile = TileConfig::new(16, 16);
    match idx {
        0 => Method::Full(tile),
        1 => Method::Anchor(AnchorConfig {
            tile,
            theta,
            step,
            init_blocks: 1,
            use_anchor: true,
        }),
        2 => Method::Streaming(StreamingConfig { tile, global_tokens: 16, local_tokens: 32 }),
        3 => Method::VerticalSlash(VerticalSlashConfig {
            tile,
            vertical_tokens: 8,
            slash_tokens: 8,
            last_q: 16,
        }),
        4 => Method::FlexPrefill(FlexPrefillConfig { tile, gamma: 0.85, min_budget_tokens: 16 }),
        _ => Method::BlockTopK(BlockTopKConfig { tile, k: 3, force_sink_local: true }),
    }
}

/// Five heads over three GQA groups — a key count none of {2, 3, 8}
/// divides, so every shard count exercises uneven partitions (and 8
/// exercises idle shards).
fn five_head_batch(seed: u64, n: usize, d: usize) -> (BatchInput, Vec<PlanKey>) {
    let mut rng = Pcg64::seeded(seed);
    let heads: Vec<HeadInput> = (0..5).map(|_| rand_head(&mut rng, n, d)).collect();
    let keys = vec![
        PlanKey::new(0, 0),
        PlanKey::new(0, 0),
        PlanKey::new(0, 1),
        PlanKey::new(0, 1),
        PlanKey::new(0, 2),
    ];
    (BatchInput::new(heads), keys)
}

fn unsharded(m: &Method, keys: &[PlanKey], kind: ExecutorKind, pipelined: bool) -> AttentionSession {
    let mut b = m.session().keys(keys.to_vec()).executor(kind);
    if pipelined {
        b = b.pipelined(true);
    }
    b.build().expect("unsharded session build")
}

fn sharded(
    m: &Method,
    shards: usize,
    keys: &[PlanKey],
    kind: ExecutorKind,
    pipelined: bool,
) -> ShardedSession {
    let mut b = m.sharded_session(shards).keys(keys.to_vec()).executor(kind);
    if pipelined {
        b = b.pipelined(true);
    }
    b.build().expect("sharded session build")
}

fn assert_outputs_bitwise(tag: &str, a: &SessionOutput, b: &SessionOutput) {
    assert_eq!(a.outputs.len(), b.outputs.len(), "{tag}: head count");
    for (h, (x, y)) in a.outputs.iter().zip(&b.outputs).enumerate() {
        assert_eq!(x.out.data, y.out.data, "{tag} head {h}: output not bitwise-equal");
        assert_eq!(x.cost, y.cost, "{tag} head {h}: cost differs");
        assert_eq!(
            x.coverage.total_covered(),
            y.coverage.total_covered(),
            "{tag} head {h}: coverage differs"
        );
    }
    for (h, (p, q)) in a.plans.iter().zip(&b.plans).enumerate() {
        assert_eq!(**p, **q, "{tag} head {h}: plan differs");
    }
    assert_eq!(
        (a.cache_hits, a.cache_misses),
        (b.cache_hits, b.cache_misses),
        "{tag}: hit accounting differs"
    );
    assert_eq!(a.ident_cost_paid, b.ident_cost_paid, "{tag}: ident attribution differs");
}

/// The wall: all six planners × shards {1, 2, 3, 8} × sequential/pipelined
/// × cpu/pjrt, cold batch and warm repeat, against the unsharded session.
#[test]
fn sharded_bitwise_equals_unsharded_for_all_six_methods() {
    let (batch, keys) = five_head_batch(0x5AAD, 96, 8);
    for method_idx in 0..6 {
        let m = method_for(method_idx, 3.0, 2);
        for kind in [ExecutorKind::Cpu, ExecutorKind::Pjrt] {
            for pipelined in [false, true] {
                let tag =
                    format!("{} ({}, pipelined={pipelined})", m.name(), kind.name());
                let mut base_session = unsharded(&m, &keys, kind, pipelined);
                let base = base_session.run_batch(&batch).unwrap();
                let base_warm = base_session.run_batch(&batch).unwrap();
                assert_eq!(
                    (base_warm.cache_hits, base_warm.cache_misses),
                    (5, 0),
                    "{tag}: unsharded warm repeat must be all hits"
                );
                for shards in [1usize, 2, 3, 8] {
                    let stag = format!("{tag} shards={shards}");
                    let mut sh = sharded(&m, shards, &keys, kind, pipelined);
                    let cold = sh
                        .run_batch(&batch)
                        .unwrap_or_else(|e| panic!("{stag}: sharded run failed: {e}"));
                    assert_outputs_bitwise(&stag, &base, &cold);
                    // Warm repeat through the shared cache: routing is
                    // invisible to amortization.
                    let warm = sh.run_batch(&batch).unwrap();
                    assert_outputs_bitwise(&format!("{stag} warm"), &base_warm, &warm);
                    assert!((warm.hit_rate() - 1.0).abs() < 1e-12, "{stag}: warm hit rate");
                }
            }
        }
    }
}

/// Randomized shapes, params, shard counts and group sizes (property
/// form of the wall, CPU sequential + pipelined to bound runtime).
#[test]
fn prop_sharded_batch_bitwise_equals_unsharded() {
    #[derive(Clone, Debug)]
    struct Case {
        seed: u64,
        n: usize,
        d: usize,
        method_idx: usize,
        theta: f32,
        step: usize,
        shards: usize,
        heads: usize,
        group: usize,
        pipelined: bool,
    }
    let cfg = Config::heavy(12, 0x58D5);
    check(
        &cfg,
        |rng| Case {
            seed: rng.next_u64(),
            n: *choose(rng, &[64, 96, 128]),
            d: *choose(rng, &[8, 16]),
            method_idx: rng.next_below(6) as usize,
            theta: *choose(rng, &[-2.0, 0.5, 3.0, 8.0]),
            step: *choose(rng, &[1, 2, 4]),
            shards: *choose(rng, &[1, 2, 3, 5, 8]),
            heads: *choose(rng, &[1, 3, 4, 6]),
            group: *choose(rng, &[1, 2, 3]),
            pipelined: rng.next_below(2) == 0,
        },
        |c| {
            let mut out = Vec::new();
            if c.shards > 1 {
                out.push(Case { shards: 1, ..c.clone() });
            }
            if c.heads > 1 {
                out.push(Case { heads: 1, ..c.clone() });
            }
            if c.pipelined {
                out.push(Case { pipelined: false, ..c.clone() });
            }
            out
        },
        |c| {
            let mut rng = Pcg64::seeded(c.seed);
            let heads: Vec<HeadInput> =
                (0..c.heads).map(|_| rand_head(&mut rng, c.n, c.d)).collect();
            let batch = BatchInput::new(heads);
            let keys: Vec<PlanKey> =
                (0..c.heads).map(|h| PlanKey::new(0, (h / c.group) as u32)).collect();
            let m = method_for(c.method_idx, c.theta, c.step);
            let base = unsharded(&m, &keys, ExecutorKind::Cpu, c.pipelined)
                .run_batch(&batch)
                .map_err(|e| e.to_string())?;
            let merged = sharded(&m, c.shards, &keys, ExecutorKind::Cpu, c.pipelined)
                .run_batch(&batch)
                .map_err(|e| format!("{}: sharded run failed: {e}", m.name()))?;
            for (h, (a, b)) in base.outputs.iter().zip(&merged.outputs).enumerate() {
                ensure(
                    a.out.data == b.out.data,
                    format!("{} head {h}: sharded output not bitwise-equal", m.name()),
                )?;
                ensure(a.cost == b.cost, format!("{} head {h}: cost differs", m.name()))?;
            }
            ensure(
                (base.cache_hits, base.cache_misses)
                    == (merged.cache_hits, merged.cache_misses),
                format!("{}: hit accounting differs", m.name()),
            )?;
            ensure(
                base.ident_cost_paid == merged.ident_cost_paid,
                format!("{}: ident attribution differs", m.name()),
            )
        },
    );
}

/// A pre-warmed shared cache (the public `shared_cache` seam) behaves
/// identically to a pre-warmed unsharded session: seeded keys hit, pay no
/// identification, and outputs stay bitwise-equal.
#[test]
fn pre_warmed_shared_cache_hits_across_shards() {
    let (batch, keys) = five_head_batch(0x7A3E, 96, 8);
    let m = method_for(1, 3.0, 2);
    // Warm a cache with every key's plan (identified from the head the
    // cached path would pick: the first head of each key).
    let warm_cache = |firsts: &[usize]| {
        let cache = Arc::new(PlanCache::new());
        for (key, &h) in [PlanKey::new(0, 0), PlanKey::new(0, 1), PlanKey::new(0, 2)]
            .iter()
            .zip(firsts)
        {
            cache.seed(*key, Arc::new(m.plan(&batch.heads[h])));
        }
        cache
    };
    let mut base = m
        .session()
        .keys(keys.clone())
        .cache(PlanCache::new())
        .build()
        .unwrap();
    let base_out = base.run_batch(&batch).unwrap();
    for shards in [2usize, 3] {
        let mut sh = m
            .sharded_session(shards)
            .keys(keys.clone())
            .shared_cache(warm_cache(&[0, 2, 4]))
            .build()
            .unwrap();
        let out = sh.run_batch(&batch).unwrap();
        assert_eq!((out.cache_hits, out.cache_misses), (5, 0), "shards={shards}");
        assert_eq!(out.ident_cost_paid.ident_scores, 0, "shards={shards}");
        for (h, (a, b)) in base_out.outputs.iter().zip(&out.outputs).enumerate() {
            assert_eq!(a.out.data, b.out.data, "shards={shards} head {h}");
        }
    }
}

/// A panicked shard worker surfaces as an error naming the shard — never
/// a coordinator crash, never a deadlock, never silent partial output.
/// The panic is induced through the public seam: a wrong-length plan
/// seeded into the shared cache trips the executor's length assertion on
/// whichever shard owns that key.
#[test]
fn panicked_shard_surfaces_error() {
    let (batch, keys) = five_head_batch(0xDEAD, 96, 8);
    let m = method_for(1, 3.0, 2);
    // Plan built for n=64 seeded under a key the n=96 batch will hit.
    let mut rng = Pcg64::seeded(1);
    let wrong = Arc::new(m.plan(&rand_head(&mut rng, 64, 8)));
    for shards in [1usize, 2, 3, 8] {
        let cache = Arc::new(PlanCache::new());
        cache.seed(PlanKey::new(0, 1), wrong.clone());
        let mut sh = m
            .sharded_session(shards)
            .keys(keys.clone())
            .shared_cache(cache)
            .build()
            .unwrap();
        let err = sh
            .run_batch(&batch)
            .expect_err("a poisoned shard must surface an error")
            .to_string();
        assert!(err.contains("shard"), "shards={shards}: error must name the shard: {err}");
        // The coordinator survives: a clean cache on the same session
        // layout still runs (fresh sharded session, same config).
        let mut ok = m.sharded_session(shards).keys(keys.clone()).build().unwrap();
        assert!(ok.run_batch(&batch).is_ok(), "shards={shards}: clean rerun");
    }
}

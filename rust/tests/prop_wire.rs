//! Fuzz-style property wall for the wire protocol (DESIGN.md §14):
//!
//! * **Round-trip** — every frame kind and every message type survives
//!   encode → frame → decode bitwise: tensors compare by raw f32 bits,
//!   plans by full coordinate/cost equality, and the header never
//!   reinterprets a byte.
//! * **Corruption is loud** — every strict truncation of a valid frame or
//!   payload is an `Err`; wrong version, bad magic, unknown kind,
//!   over-cap length, and trailing bytes are all rejected with
//!   descriptive messages.
//! * **Corruption never panics** — random single-bit flips anywhere in a
//!   frame either decode to a valid value or return `Err`; hostile
//!   all-0xFF buffers (giant declared counts) are rejected by the
//!   pre-allocation guards in every message decoder.
//!
//! Uses the homegrown `util::proptest` harness (proptest itself is
//! unavailable offline), mirroring `prop_shard_parity.rs` idiom.

use std::sync::Arc;

use anchor_attention::attention::anchor::AnchorConfig;
use anchor_attention::attention::baselines::block_topk::BlockTopKConfig;
use anchor_attention::attention::baselines::flexprefill::FlexPrefillConfig;
use anchor_attention::attention::baselines::streaming::StreamingConfig;
use anchor_attention::attention::baselines::vertical_slash::VerticalSlashConfig;
use anchor_attention::attention::exec::ExecutorKind;
use anchor_attention::attention::pipeline::PipelineStats;
use anchor_attention::attention::plan::{PlanKey, SparsePlan};
use anchor_attention::attention::{CostTally, HeadInput, Method, TileConfig};
use anchor_attention::tensor::Mat;
use anchor_attention::util::proptest::{check, ensure, Config};
use anchor_attention::util::rng::Pcg64;
use anchor_attention::wire::codec::{
    ConfigureMsg, DispatchMsg, ErrorEnvelope, HealthReplyMsg, MetricsReplyMsg, ReplyMsg,
    ReqReplyMsg, ReqSubmitMsg, StatusCode,
};
use anchor_attention::wire::frame::{
    decode_frame_bytes, encode_frame, read_frame, read_frame_opt, write_frame, FrameKind,
    HEADER_BYTES, MAX_FRAME_BYTES, WIRE_VERSION,
};

const ALL_KINDS: [FrameKind; 14] = [
    FrameKind::Configure,
    FrameKind::Ready,
    FrameKind::Dispatch,
    FrameKind::Reply,
    FrameKind::Error,
    FrameKind::Ping,
    FrameKind::Pong,
    FrameKind::Shutdown,
    FrameKind::ReqSubmit,
    FrameKind::ReqReply,
    FrameKind::Health,
    FrameKind::HealthReply,
    FrameKind::Metrics,
    FrameKind::MetricsReply,
];

const ALL_STATUS: [StatusCode; 6] = [
    StatusCode::Ok,
    StatusCode::Invalid,
    StatusCode::Oversized,
    StatusCode::Overloaded,
    StatusCode::Failed,
    StatusCode::Internal,
];

fn rand_head(rng: &mut Pcg64, n: usize, d: usize) -> HeadInput {
    HeadInput::new(
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
    )
}

fn method_for(idx: usize, theta: f32, step: usize) -> Method {
    let tile = TileConfig::new(16, 16);
    match idx {
        0 => Method::Full(tile),
        1 => Method::Anchor(AnchorConfig {
            tile,
            theta,
            step,
            init_blocks: 1,
            use_anchor: true,
        }),
        2 => Method::Streaming(StreamingConfig { tile, global_tokens: 16, local_tokens: 32 }),
        3 => Method::VerticalSlash(VerticalSlashConfig {
            tile,
            vertical_tokens: 8,
            slash_tokens: 8,
            last_q: 16,
        }),
        4 => Method::FlexPrefill(FlexPrefillConfig { tile, gamma: 0.85, min_budget_tokens: 16 }),
        _ => Method::BlockTopK(BlockTopKConfig { tile, k: 3, force_sink_local: true }),
    }
}

fn rand_tally(rng: &mut Pcg64) -> CostTally {
    CostTally {
        flops: rng.next_below(1 << 40),
        kv_bytes: rng.next_below(1 << 33),
        ident_scores: rng.next_below(1 << 20),
    }
}

fn mats_bitwise_equal(a: &Mat, b: &Mat) -> bool {
    a.rows == b.rows
        && a.cols == b.cols
        && a.data.iter().map(|x| x.to_bits()).eq(b.data.iter().map(|x| x.to_bits()))
}

/// One randomized wire scenario: shapes, method, seed-plan count.
#[derive(Clone, Debug)]
struct WireCase {
    seed: u64,
    n: usize,
    heads: usize,
    method_idx: usize,
    seeds: usize,
    pipelined: bool,
}

fn gen_case(rng: &mut Pcg64) -> WireCase {
    WireCase {
        seed: rng.next_u64(),
        n: 32 + rng.next_below(64) as usize,
        heads: 1 + rng.next_below(4) as usize,
        method_idx: rng.next_below(6) as usize,
        seeds: rng.next_below(3) as usize,
        pipelined: rng.next_below(2) == 1,
    }
}

fn shrink_case(c: &WireCase) -> Vec<WireCase> {
    let mut out = Vec::new();
    if c.n > 32 {
        out.push(WireCase { n: 32, ..c.clone() });
    }
    if c.heads > 1 {
        out.push(WireCase { heads: 1, ..c.clone() });
    }
    if c.seeds > 0 {
        out.push(WireCase { seeds: 0, ..c.clone() });
    }
    if c.method_idx > 0 {
        out.push(WireCase { method_idx: 0, ..c.clone() });
    }
    out
}

/// Build a representative Dispatch message: real planner plans as cache
/// seeds, random Q/K/V heads, GQA-style repeated keys.
fn dispatch_for(c: &WireCase) -> DispatchMsg {
    let mut rng = Pcg64::seeded(c.seed);
    let d = 8;
    let m = method_for(c.method_idx, 3.0, 2);
    let heads: Vec<HeadInput> = (0..c.heads).map(|_| rand_head(&mut rng, c.n, d)).collect();
    let keys: Vec<PlanKey> =
        (0..c.heads).map(|i| PlanKey::new((i % 2) as u32, (i % 3) as u32)).collect();
    let seeds: Vec<(PlanKey, Arc<SparsePlan>)> = (0..c.seeds)
        .map(|i| (PlanKey::new(9, i as u32), Arc::new(m.plan(&heads[i % heads.len()]))))
        .collect();
    DispatchMsg { seq: rng.next_u64(), keys, seeds, heads }
}

/// Build a representative Reply message: output rows, deduplicated real
/// plans, accounting counters, optional pipeline stats.
fn reply_for(c: &WireCase) -> (ReplyMsg, usize) {
    let mut rng = Pcg64::seeded(c.seed ^ 0xA5A5);
    let d = 8;
    let m = method_for(c.method_idx, 3.0, 2);
    let plan_heads: Vec<HeadInput> =
        (0..c.heads.min(2)).map(|_| rand_head(&mut rng, c.n, d)).collect();
    let plans: Vec<Arc<SparsePlan>> =
        plan_heads.iter().map(|h| Arc::new(m.plan(h))).collect();
    let outs: Vec<(Mat, CostTally)> = (0..c.heads)
        .map(|_| (Mat::from_fn(c.n, d, |_, _| rng.normal()), rand_tally(&mut rng)))
        .collect();
    let plan_of: Vec<u32> = (0..c.heads).map(|i| (i % plans.len()) as u32).collect();
    let pipeline = c.pipelined.then(|| PipelineStats {
        ident_total_s: 0.5,
        ident_hidden_s: 0.25,
        exec_total_s: 1.5,
        stall_s: 0.25,
        wall_s: 2.0,
        items: c.heads,
    });
    let msg = ReplyMsg {
        seq: rng.next_u64(),
        outs,
        plan_of,
        plans,
        cache_hits: rng.next_below(1 << 16),
        cache_misses: rng.next_below(1 << 16),
        ident_paid: rand_tally(&mut rng),
        pipeline,
    };
    (msg, d)
}

// ---------------------------------------------------------------------------
// Round-trip
// ---------------------------------------------------------------------------

/// Every frame kind round-trips through bytes and through a stream, and
/// a clean EOF at the frame boundary is `Ok(None)` — never an error.
#[test]
fn every_frame_kind_round_trips() {
    let payloads: [&[u8]; 3] = [b"", b"x", &[0xABu8; 257]];
    for kind in ALL_KINDS {
        for payload in payloads {
            let buf = encode_frame(kind, payload);
            assert_eq!(buf.len(), HEADER_BYTES + payload.len());
            let (k, body) = decode_frame_bytes(&buf).unwrap();
            assert_eq!((k, body), (kind, payload), "{kind:?} byte round-trip");

            let mut stream: Vec<u8> = Vec::new();
            write_frame(&mut stream, kind, payload).unwrap();
            assert_eq!(stream, buf, "{kind:?}: write_frame must equal encode_frame");
            let mut r = std::io::Cursor::new(stream);
            let (k2, p2) = read_frame(&mut r).unwrap();
            assert_eq!((k2, p2.as_slice()), (kind, payload), "{kind:?} stream round-trip");
            assert!(read_frame_opt(&mut r).unwrap().is_none(), "clean EOF is Ok(None)");
        }
    }
}

/// Property: random payload bytes round-trip under every kind.
#[test]
fn prop_random_payloads_round_trip() {
    let cfg = Config { cases: 64, seed: 0x31BE, ..Default::default() };
    check(
        &cfg,
        |rng| {
            let len = rng.next_below(2048) as usize;
            let kind_idx = rng.next_below(ALL_KINDS.len() as u64) as usize;
            let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            (kind_idx, bytes)
        },
        |(k, bytes)| {
            let mut out = Vec::new();
            if !bytes.is_empty() {
                out.push((*k, bytes[..bytes.len() / 2].to_vec()));
                out.push((*k, Vec::new()));
            }
            out
        },
        |(kind_idx, bytes)| {
            let kind = ALL_KINDS[*kind_idx];
            let buf = encode_frame(kind, bytes);
            let (k, body) = decode_frame_bytes(&buf).map_err(|e| e.to_string())?;
            ensure(k == kind, format!("kind {k:?} != {kind:?}"))?;
            ensure(body == &bytes[..], "payload bytes differ")
        },
    );
}

/// Deterministic round-trip of every fixed-shape control message:
/// Configure across all six methods, both executors, and both flag
/// settings; ErrorEnvelope across all six status codes; the request
/// envelope with edge-case token values; health and metrics replies.
#[test]
fn control_messages_round_trip_exactly() {
    for idx in 0..6 {
        for (e_i, executor) in [ExecutorKind::Cpu, ExecutorKind::Pjrt].into_iter().enumerate() {
            let msg = ConfigureMsg {
                shard_id: (idx * 2 + e_i) as u32,
                method: method_for(idx, 2.5, 3),
                executor,
                pipelined: idx % 2 == 0,
                cache: idx % 2 == 1,
            };
            let back = ConfigureMsg::decode(&msg.encode()).unwrap();
            assert_eq!(back, msg, "configure method {idx} executor {e_i}");
        }
    }

    for status in ALL_STATUS {
        let msg = ErrorEnvelope::new(status, format!("detail for {}", status.name()));
        assert_eq!(ErrorEnvelope::decode(&msg.encode()).unwrap(), msg);
    }

    // Prompt tokens are i32 (negative sentinels included); arrival times
    // are raw f64 bits.
    let submits = [
        ReqSubmitMsg { id: 0, prompt: vec![], max_new_tokens: 0, arrival_s: 0.0 },
        ReqSubmitMsg { id: 7, prompt: vec![1, -1, i32::MAX, i32::MIN], max_new_tokens: 64, arrival_s: -1.5 },
        ReqSubmitMsg { id: u64::MAX, prompt: vec![42; 300], max_new_tokens: u64::MAX, arrival_s: 1e300 },
    ];
    for msg in submits {
        assert_eq!(ReqSubmitMsg::decode(&msg.encode()).unwrap(), msg);
    }

    let reply = ReqReplyMsg {
        id: 3,
        status: StatusCode::Overloaded,
        detail: "queue full (2 pending); retry later — ¡überfüllt!".to_string(),
    };
    assert_eq!(ReqReplyMsg::decode(&reply.encode()).unwrap(), reply);

    let health = HealthReplyMsg { queued: 12, capacity: 0 };
    assert_eq!(HealthReplyMsg::decode(&health.encode()).unwrap(), health);

    let metrics = MetricsReplyMsg { json: "{\"completed\": 2, \"π\": 3.14}".to_string() };
    assert_eq!(MetricsReplyMsg::decode(&metrics.encode()).unwrap(), metrics);
}

/// Property: a Dispatch built from real planner plans round-trips
/// bitwise — keys, seed plans (coordinates + cost), and Q/K/V tensors by
/// raw f32 bits. DispatchMsg has no PartialEq (tensors), so fields are
/// compared explicitly.
#[test]
fn prop_dispatch_round_trips_bitwise() {
    let cfg = Config::heavy(12, 0xD15B);
    check(&cfg, gen_case, shrink_case, |c| {
        let msg = dispatch_for(c);
        let buf = encode_frame(FrameKind::Dispatch, &msg.encode());
        let (kind, payload) = decode_frame_bytes(&buf).map_err(|e| e.to_string())?;
        ensure(kind == FrameKind::Dispatch, "frame kind")?;
        let back = DispatchMsg::decode(payload).map_err(|e| format!("decode: {e}"))?;
        ensure(back.seq == msg.seq, "seq differs")?;
        ensure(back.keys == msg.keys, "keys differ")?;
        ensure(back.seeds.len() == msg.seeds.len(), "seed count differs")?;
        for ((ka, pa), (kb, pb)) in msg.seeds.iter().zip(&back.seeds) {
            ensure(ka == kb, "seed key differs")?;
            ensure(**pa == **pb, "seed plan differs")?;
        }
        ensure(back.heads.len() == msg.heads.len(), "head count differs")?;
        for (h, (a, b)) in msg.heads.iter().zip(&back.heads).enumerate() {
            for (name, x, y) in [("q", &a.q, &b.q), ("k", &a.k, &b.k), ("v", &a.v, &b.v)] {
                ensure(
                    mats_bitwise_equal(x, y),
                    format!("head {h} {name} not bitwise-equal"),
                )?;
            }
        }
        Ok(())
    });
}

/// Property: a Reply round-trips bitwise — output rows by raw f32 bits,
/// deduplicated plans by full equality, counters and pipeline stats
/// exactly. ReplyMsg has no PartialEq (tensors), so fields are compared
/// explicitly.
#[test]
fn prop_reply_round_trips_bitwise() {
    let cfg = Config::heavy(12, 0x4E97);
    check(&cfg, gen_case, shrink_case, |c| {
        let (msg, d) = reply_for(c);
        let buf = encode_frame(FrameKind::Reply, &msg.encode(d));
        let (kind, payload) = decode_frame_bytes(&buf).map_err(|e| e.to_string())?;
        ensure(kind == FrameKind::Reply, "frame kind")?;
        let back = ReplyMsg::decode(payload).map_err(|e| format!("decode: {e}"))?;
        ensure(back.seq == msg.seq, "seq differs")?;
        ensure(back.outs.len() == msg.outs.len(), "output count differs")?;
        for (h, ((ma, ca), (mb, cb))) in msg.outs.iter().zip(&back.outs).enumerate() {
            ensure(mats_bitwise_equal(ma, mb), format!("out {h} not bitwise-equal"))?;
            ensure(ca == cb, format!("out {h} cost differs"))?;
        }
        ensure(back.plan_of == msg.plan_of, "plan_of differs")?;
        ensure(back.plans.len() == msg.plans.len(), "plan count differs")?;
        for (i, (pa, pb)) in msg.plans.iter().zip(&back.plans).enumerate() {
            ensure(**pa == **pb, format!("plan {i} differs"))?;
        }
        ensure(
            (back.cache_hits, back.cache_misses) == (msg.cache_hits, msg.cache_misses),
            "hit accounting differs",
        )?;
        ensure(back.ident_paid == msg.ident_paid, "ident_paid differs")?;
        ensure(back.pipeline == msg.pipeline, "pipeline stats differ")
    });
}

// ---------------------------------------------------------------------------
// Corruption: loud rejection, never a panic
// ---------------------------------------------------------------------------

/// Every strict truncation of a valid frame fails frame decode, and every
/// strict truncation of a valid message payload fails message decode —
/// the decoders are deterministic stream reads, so missing bytes always
/// surface before a value is constructed.
#[test]
fn every_truncation_is_rejected() {
    let c = WireCase { seed: 9, n: 32, heads: 1, method_idx: 1, seeds: 1, pipelined: true };
    let dispatch = dispatch_for(&c).encode();
    let (reply_msg, d) = reply_for(&c);
    let reply = reply_msg.encode(d);
    let configure = ConfigureMsg {
        shard_id: 1,
        method: method_for(1, 3.0, 2),
        executor: ExecutorKind::Cpu,
        pipelined: false,
        cache: true,
    }
    .encode();

    let frame = encode_frame(FrameKind::Dispatch, &dispatch);
    for cut in 0..frame.len() {
        assert!(
            decode_frame_bytes(&frame[..cut]).is_err(),
            "frame truncated to {cut}/{} bytes must be rejected",
            frame.len()
        );
    }

    for cut in 0..dispatch.len() {
        assert!(
            DispatchMsg::decode(&dispatch[..cut]).is_err(),
            "dispatch payload truncated to {cut}/{} bytes must be rejected",
            dispatch.len()
        );
    }
    for cut in 0..reply.len() {
        assert!(
            ReplyMsg::decode(&reply[..cut]).is_err(),
            "reply payload truncated to {cut}/{} bytes must be rejected",
            reply.len()
        );
    }
    for cut in 0..configure.len() {
        assert!(
            ConfigureMsg::decode(&configure[..cut]).is_err(),
            "configure payload truncated to {cut}/{} bytes must be rejected",
            configure.len()
        );
    }

    // EOF inside a frame on the stream path is corruption-loud, not
    // Ok(None): the header promises a payload that never arrives.
    let mut r = std::io::Cursor::new(frame[..HEADER_BYTES + 3].to_vec());
    assert!(read_frame(&mut r).is_err());
}

/// Property: flipping any single bit of a valid frame either yields a
/// descriptive `Err` or decodes to some valid value — never a panic. In
/// the header, only the kind field can survive a flip (onto another
/// valid kind); magic, version, and length flips must always be
/// rejected.
#[test]
fn prop_single_bit_flips_never_panic() {
    let c = WireCase { seed: 11, n: 32, heads: 2, method_idx: 1, seeds: 1, pipelined: false };
    let frame = encode_frame(FrameKind::Dispatch, &dispatch_for(&c).encode());
    let cfg = Config { cases: 256, seed: 0xF11B, ..Default::default() };
    let len = frame.len();
    check(
        &cfg,
        move |rng| (rng.next_below(len as u64) as usize, rng.next_below(8) as u8),
        |&(idx, bit)| {
            let mut out = Vec::new();
            if idx > 0 {
                out.push((0, bit));
                out.push((idx / 2, bit));
            }
            if bit > 0 {
                out.push((idx, 0));
            }
            out
        },
        |&(idx, bit)| {
            let mut buf = frame.clone();
            buf[idx] ^= 1 << bit;
            match decode_frame_bytes(&buf) {
                Err(_) => Ok(()), // loud rejection is the expected outcome
                Ok((_, payload)) => {
                    // Header flips can only survive in the kind field
                    // (bytes 6..8): magic, version, and length are pinned.
                    ensure(
                        idx >= HEADER_BYTES || (6..8).contains(&idx),
                        format!("header flip at byte {idx} bit {bit} must be rejected"),
                    )?;
                    // Message decode over a corrupted payload must return
                    // a Result, not panic; either verdict is acceptable.
                    let _ = DispatchMsg::decode(payload);
                    let _ = ReplyMsg::decode(payload);
                    Ok(())
                }
            }
        },
    );
}

/// Header-field corruption is rejected with a message naming the field,
/// and hostile all-0xFF buffers (declared counts far beyond the payload)
/// are rejected by every message decoder's pre-allocation guards.
#[test]
fn hostile_headers_and_buffers_are_rejected() {
    let base = encode_frame(FrameKind::Ping, b"x");

    let mut wrong_version = base.clone();
    wrong_version[4] = (WIRE_VERSION + 1) as u8;
    let err = decode_frame_bytes(&wrong_version).unwrap_err().to_string();
    assert!(err.contains("version"), "version error: {err}");

    let mut bad_magic = base.clone();
    bad_magic[0] ^= 0xFF;
    let err = decode_frame_bytes(&bad_magic).unwrap_err().to_string();
    assert!(err.contains("magic"), "magic error: {err}");

    let mut bad_kind = base.clone();
    bad_kind[6] = 99;
    let err = decode_frame_bytes(&bad_kind).unwrap_err().to_string();
    assert!(err.contains("kind"), "kind error: {err}");

    // Declared length over the frame cap is rejected before any read of
    // the body.
    let mut over = Vec::new();
    over.extend_from_slice(&base[..8]);
    over.extend_from_slice(&((MAX_FRAME_BYTES as u32) + 1).to_le_bytes());
    let err = decode_frame_bytes(&over).unwrap_err().to_string();
    assert!(err.contains("exceeds"), "over-length error: {err}");

    let mut trailing = base.clone();
    trailing.push(0);
    assert!(decode_frame_bytes(&trailing).is_err(), "trailing bytes must be rejected");

    assert!(decode_frame_bytes(&base[..5]).is_err(), "sub-header buffer must be rejected");

    // All-0xFF buffers declare absurd element counts; the seq_len /
    // geometry guards must reject them before allocating.
    for len in [0usize, 1, 2, 3, 7, 9, 33, 64] {
        let buf = vec![0xFFu8; len];
        assert!(ConfigureMsg::decode(&buf).is_err(), "configure 0xFF×{len}");
        assert!(DispatchMsg::decode(&buf).is_err(), "dispatch 0xFF×{len}");
        assert!(ReplyMsg::decode(&buf).is_err(), "reply 0xFF×{len}");
        assert!(ReqSubmitMsg::decode(&buf).is_err(), "req-submit 0xFF×{len}");
        assert!(ReqReplyMsg::decode(&buf).is_err(), "req-reply 0xFF×{len}");
        assert!(ErrorEnvelope::decode(&buf).is_err(), "error-envelope 0xFF×{len}");
        assert!(HealthReplyMsg::decode(&buf).is_err(), "health 0xFF×{len}");
        assert!(MetricsReplyMsg::decode(&buf).is_err(), "metrics 0xFF×{len}");
    }
}

//! Property tests for the workload subsystem (scenario library + arrival
//! processes + legacy Poisson trace) and a smoke test of the `bench
//! serve` harness: the invariants DESIGN.md §16 commits to — ordered
//! arrivals, byte-for-byte seed determinism, honest mixture weights,
//! shared-prefix reuse — hold across seeds, not just at one lucky one.

use std::collections::HashMap;

use anchor_attention::experiments::serve_bench::{run_with, ServeBenchOptions};
use anchor_attention::experiments::ExpScale;
use anchor_attention::util::rng::Pcg64;
use anchor_attention::workload::arrival::ArrivalProcess;
use anchor_attention::workload::scenario::{named_scenario, stream_digest, ScenarioKind};
use anchor_attention::workload::trace::{generate_trace, TraceConfig};

fn processes() -> Vec<(&'static str, ArrivalProcess)> {
    vec![
        ("poisson", ArrivalProcess::Poisson { rate: 8.0 }),
        (
            "onoff",
            ArrivalProcess::OnOff { burst_rate: 40.0, mean_on_s: 0.3, mean_off_s: 1.1 },
        ),
        ("ramp", ArrivalProcess::Ramp { start_rate: 2.0, end_rate: 20.0, ramp_s: 6.0 }),
    ]
}

#[test]
fn arrivals_are_nondecreasing_positive_and_deterministic() {
    for (name, p) in processes() {
        for seed in 0..8u64 {
            let mut rng = Pcg64::seeded(seed);
            let ts = p.sample(&mut rng, 300);
            assert_eq!(ts.len(), 300, "{name}");
            assert!(ts[0] > 0.0, "{name} seed {seed}: first arrival {}", ts[0]);
            assert!(
                ts.windows(2).all(|w| w[0] <= w[1] && w[1].is_finite()),
                "{name} seed {seed}: arrivals not ordered"
            );
            let mut rng2 = Pcg64::seeded(seed);
            assert_eq!(ts, p.sample(&mut rng2, 300), "{name} seed {seed}: not deterministic");
        }
    }
}

#[test]
fn scenario_streams_are_byte_for_byte_deterministic_per_seed() {
    for name in ["long-doc", "rag", "shared-prefix", "needle", "mixed"] {
        for seed in [0u64, 1, 99] {
            let cfg = named_scenario(name, 48, seed).unwrap();
            let a = cfg.generate().unwrap();
            let b = cfg.generate().unwrap();
            assert_eq!(a, b, "{name} seed {seed}");
            assert_eq!(stream_digest(&a), stream_digest(&b), "{name} seed {seed}");
        }
        // Different seeds must not collide (the digest is the CI's
        // determinism witness — it has to actually depend on the seed).
        let d0 = stream_digest(&named_scenario(name, 48, 0).unwrap().generate().unwrap());
        let d1 = stream_digest(&named_scenario(name, 48, 1).unwrap().generate().unwrap());
        assert_ne!(d0, d1, "{name}: digest ignores the seed");
    }
}

#[test]
fn trace_mixture_weights_hold_at_large_n() {
    let cfg = TraceConfig {
        rate: 20.0,
        num_requests: 4000,
        length_mix: vec![(128, 0.5), (512, 0.3), (1024, 0.2)],
        decode_min: 1,
        decode_max: 8,
        seed: 3,
    };
    let trace = generate_trace(&cfg).unwrap();
    let mut counts: HashMap<usize, usize> = HashMap::new();
    for r in &trace {
        *counts.entry(r.prompt_tokens).or_insert(0) += 1;
        assert!((cfg.decode_min..=cfg.decode_max).contains(&r.decode_tokens));
    }
    for (len, w) in &cfg.length_mix {
        let frac = counts[len] as f64 / trace.len() as f64;
        assert!(
            (frac - w).abs() < 0.05,
            "length {len}: fraction {frac:.3} vs weight {w}"
        );
    }
}

#[test]
fn shared_prefix_groups_reuse_identical_prefix_lengths() {
    let cfg = named_scenario("shared-prefix", 64, 17).unwrap();
    let trace = cfg.generate().unwrap();
    // Every request in a group carries the same prefix length and the
    // same reuse key — that is what makes plan-cache hits attributable.
    let mut by_group: HashMap<u32, (usize, u64)> = HashMap::new();
    let mut groups_seen = 0;
    for r in &trace {
        assert_eq!(r.kind, ScenarioKind::SharedPrefix);
        let g = r.prefix_group.expect("shared-prefix requests are grouped");
        assert!(r.prefix_tokens > 0 && r.prefix_tokens < r.prompt_tokens, "{r:?}");
        match by_group.get(&g) {
            None => {
                by_group.insert(g, (r.prefix_tokens, r.reuse_key));
                groups_seen += 1;
            }
            Some(&(prefix, key)) => {
                assert_eq!(r.prefix_tokens, prefix, "group {g} prefix drifted");
                assert_eq!(r.reuse_key, key, "group {g} reuse key drifted");
            }
        }
    }
    assert!(groups_seen > 1, "want multiple prefix groups, got {groups_seen}");
    assert!(trace.len() > groups_seen, "groups must be shared across requests");
}

/// End-to-end smoke: a tiny mixed trace through the real serve path
/// produces a schema-valid report with the fields the CI gate reads.
#[test]
fn serve_harness_produces_schema_valid_report() {
    let opts = ServeBenchOptions {
        scenario: "mixed".to_string(),
        requests: Some(12),
        baseline: None,
    };
    let rep = run_with(ExpScale::Quick, 0, &opts).unwrap();
    assert_eq!(rep.get("experiment").as_str(), Some("serve_bench"));
    assert_eq!(rep.get("mode").as_str(), Some("mixed"));
    for key in [
        "p50_ttft_s",
        "p95_ttft_s",
        "p99_ttft_s",
        "p99_e2e_s",
        "goodput_per_core",
        "wall_s",
        "kv_evictions",
        "peak_queue_depth",
    ] {
        let v = rep.get(key).as_f64().unwrap_or_else(|| panic!("missing {key}"));
        assert!(v.is_finite() && v >= 0.0, "{key} = {v}");
    }
    assert!(rep.get("p99_ttft_s").as_f64() <= rep.get("p99_e2e_s").as_f64());
    assert!(rep.get("goodput_per_core").as_f64().unwrap() > 0.0);
    assert_eq!(rep.get("stream_digest").as_str().unwrap().len(), 16);
    // Every scenario in the mix shows up as a row with attribution
    // fields; all twelve requests complete (the pool outsizes this trace).
    let rows = rep.get("rows").as_arr().unwrap();
    assert!(!rows.is_empty());
    let mut tags: Vec<&str> = rows.iter().filter_map(|r| r.get("scenario").as_str()).collect();
    tags.sort_unstable();
    assert_eq!(tags, vec!["long-doc", "needle", "rag", "shared-prefix"]);
    let completed: f64 =
        rows.iter().map(|r| r.get("completed").as_f64().unwrap()).sum();
    assert_eq!(completed as usize, rep.get("requests").as_usize().unwrap());
    for row in rows {
        for key in ["requests", "completed", "plan_hits", "plan_misses", "plan_hit_rate"] {
            assert!(row.get(key).as_f64().is_some(), "row missing {key}");
        }
    }
    // Determinism end to end: a second run reproduces the same stream
    // and the same per-scenario request counts.
    let again = run_with(ExpScale::Quick, 0, &opts).unwrap();
    assert_eq!(
        rep.get("stream_digest").as_str(),
        again.get("stream_digest").as_str()
    );
    assert_eq!(rep.get("rows").as_arr().unwrap().len(), again.get("rows").as_arr().unwrap().len());
}

/// The reuse gradient the gate depends on, measured through the harness:
/// shared-prefix (8 groups over many requests) hits the plan cache,
/// needle (unique keys) does not.
#[test]
fn shared_prefix_hits_beat_needle_through_the_harness() {
    let shared = run_with(
        ExpScale::Quick,
        7,
        &ServeBenchOptions {
            scenario: "shared-prefix".to_string(),
            requests: Some(24),
            baseline: None,
        },
    )
    .unwrap();
    let needle = run_with(
        ExpScale::Quick,
        7,
        &ServeBenchOptions {
            scenario: "needle".to_string(),
            requests: Some(24),
            baseline: None,
        },
    )
    .unwrap();
    let hit_rate = |rep: &anchor_attention::util::json::Json, tag: &str| {
        rep.get("rows")
            .as_arr()
            .unwrap()
            .iter()
            .find(|r| r.get("scenario").as_str() == Some(tag))
            .and_then(|r| r.get("plan_hit_rate").as_f64())
            .unwrap()
    };
    let sp = hit_rate(&shared, "shared-prefix");
    let nd = hit_rate(&needle, "needle");
    // 24 requests over 8 prefix groups ⇒ at least 2/3 hits; needle keys
    // are unique ⇒ zero.
    assert!(sp > 0.5, "shared-prefix hit rate {sp}");
    assert_eq!(nd, 0.0, "needle hit rate {nd}");
}

//! Sharded-over-wire ≡ sharded-over-threads parity wall (DESIGN.md §14).
//!
//! Extends the §12 shard parity wall across a real process boundary: the
//! coordinator talks to `anchor-attn worker` child processes over framed
//! UDS/TCP sockets, and the merged output must stay **bitwise-equal** to
//! the in-thread `ShardedSession` — outputs, per-head costs, plan
//! coordinates, hit/miss accounting, and ident attribution — for all six
//! planners × process shards {1, 2, 3}, cold and warm.
//!
//! Failure modes are loud and recoverable at batch granularity:
//! * a worker killed between dispatches surfaces as an `Err` naming the
//!   shard, and the next batch succeeds once a fresh worker listens;
//! * an unreachable endpoint fails the batch naming the shard while the
//!   surviving worker keeps serving;
//! * a worker that accepts but never answers trips the read deadline.
//!
//! Runs the actual binary (`CARGO_BIN_EXE_anchor-attn`), so this is also
//! the CI `wire-parity` gate's in-tree half.

use std::io::BufRead;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anchor_attention::attention::anchor::AnchorConfig;
use anchor_attention::attention::baselines::block_topk::BlockTopKConfig;
use anchor_attention::attention::baselines::flexprefill::FlexPrefillConfig;
use anchor_attention::attention::baselines::streaming::StreamingConfig;
use anchor_attention::attention::baselines::vertical_slash::VerticalSlashConfig;
use anchor_attention::attention::exec::ExecutorKind;
use anchor_attention::attention::plan::{BatchInput, PlanKey};
use anchor_attention::attention::session::SessionOutput;
use anchor_attention::attention::shard::ShardedSession;
use anchor_attention::attention::{HeadInput, Method, TileConfig};
use anchor_attention::tensor::Mat;
use anchor_attention::util::rng::Pcg64;
use anchor_attention::wire::{RemoteSpec, ShardEndpoint, WireTimeouts};

const BIN: &str = env!("CARGO_BIN_EXE_anchor-attn");

fn rand_head(rng: &mut Pcg64, n: usize, d: usize) -> HeadInput {
    HeadInput::new(
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
        Mat::from_fn(n, d, |_, _| rng.normal()),
    )
}

fn method_for(idx: usize) -> Method {
    let tile = TileConfig::new(16, 16);
    match idx {
        0 => Method::Full(tile),
        1 => Method::Anchor(AnchorConfig {
            tile,
            theta: 3.0,
            step: 2,
            init_blocks: 1,
            use_anchor: true,
        }),
        2 => Method::Streaming(StreamingConfig { tile, global_tokens: 16, local_tokens: 32 }),
        3 => Method::VerticalSlash(VerticalSlashConfig {
            tile,
            vertical_tokens: 8,
            slash_tokens: 8,
            last_q: 16,
        }),
        4 => Method::FlexPrefill(FlexPrefillConfig { tile, gamma: 0.85, min_budget_tokens: 16 }),
        _ => Method::BlockTopK(BlockTopKConfig { tile, k: 3, force_sink_local: true }),
    }
}

/// Five heads over three GQA groups — both workers of a 2-shard split and
/// all three of a 3-shard split stay non-empty.
fn five_head_batch(seed: u64, n: usize, d: usize) -> (BatchInput, Vec<PlanKey>) {
    let mut rng = Pcg64::seeded(seed);
    let heads: Vec<HeadInput> = (0..5).map(|_| rand_head(&mut rng, n, d)).collect();
    let keys = vec![
        PlanKey::new(0, 0),
        PlanKey::new(0, 0),
        PlanKey::new(0, 1),
        PlanKey::new(0, 1),
        PlanKey::new(0, 2),
    ];
    (BatchInput::new(heads), keys)
}

fn assert_outputs_bitwise(tag: &str, a: &SessionOutput, b: &SessionOutput) {
    assert_eq!(a.outputs.len(), b.outputs.len(), "{tag}: head count");
    for (h, (x, y)) in a.outputs.iter().zip(&b.outputs).enumerate() {
        assert_eq!(x.out.data, y.out.data, "{tag} head {h}: output not bitwise-equal");
        assert_eq!(x.cost, y.cost, "{tag} head {h}: cost differs");
    }
    for (h, (p, q)) in a.plans.iter().zip(&b.plans).enumerate() {
        assert_eq!(**p, **q, "{tag} head {h}: plan differs");
    }
    assert_eq!(
        (a.cache_hits, a.cache_misses),
        (b.cache_hits, b.cache_misses),
        "{tag}: hit accounting differs"
    );
    assert_eq!(a.ident_cost_paid, b.ident_cost_paid, "{tag}: ident attribution differs");
}

fn sock_path(tag: &str) -> PathBuf {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "anchor-parity-{}-{}-{}.sock",
        std::process::id(),
        tag,
        COUNTER.fetch_add(1, Ordering::Relaxed)
    ))
}

/// A pre-started worker child; killed and reaped on drop.
struct WorkerGuard(Child);

impl WorkerGuard {
    fn spawn_uds(path: &std::path::Path) -> Self {
        let child = Command::new(BIN)
            .arg("worker")
            .arg("--uds")
            .arg(path)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .spawn()
            .expect("spawn worker");
        WorkerGuard(child)
    }

    fn kill(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        self.kill();
    }
}

fn quick_timeouts() -> WireTimeouts {
    WireTimeouts {
        connect: Duration::from_secs(10),
        read: Duration::from_secs(30),
        retries: 2,
        backoff: Duration::from_millis(50),
    }
}

fn thread_session(m: &Method, shards: usize, keys: &[PlanKey], kind: ExecutorKind) -> ShardedSession {
    m.sharded_session(shards)
        .keys(keys.to_vec())
        .executor(kind)
        .build()
        .expect("thread session build")
}

fn spawned_session(m: &Method, shards: usize, keys: &[PlanKey], kind: ExecutorKind) -> ShardedSession {
    m.sharded_session(shards)
        .keys(keys.to_vec())
        .executor(kind)
        .remote(RemoteSpec::Spawn { program: Some(PathBuf::from(BIN)) })
        .wire_timeouts(quick_timeouts())
        .build()
        .expect("spawned session build")
}

/// The acceptance wall: all six planners × spawned process shards
/// {1, 2, 3}, cold batch and warm repeat, bitwise against the in-thread
/// sharded session.
#[test]
fn process_shards_bitwise_equal_thread_shards_for_all_six_methods() {
    let (batch, keys) = five_head_batch(0x3B1E, 96, 8);
    for method_idx in 0..6 {
        let m = method_for(method_idx);
        for shards in [1usize, 2, 3] {
            let tag = format!("{} over {shards} process shard(s)", m.name());
            let mut threads = thread_session(&m, shards, &keys, ExecutorKind::Cpu);
            let mut procs = spawned_session(&m, shards, &keys, ExecutorKind::Cpu);
            let cold_t = threads.run_batch(&batch).unwrap();
            let cold_p = procs.run_batch(&batch).unwrap();
            assert_outputs_bitwise(&format!("{tag} (cold)"), &cold_t, &cold_p);
            let warm_t = threads.run_batch(&batch).unwrap();
            let warm_p = procs.run_batch(&batch).unwrap();
            assert_outputs_bitwise(&format!("{tag} (warm)"), &warm_t, &warm_p);
            assert!(
                warm_p.cache_hits > 0,
                "{tag}: warm run must hit the coordinator-seeded cache"
            );
        }
    }
}

/// Pipelined dispatch and the PJRT backend cross the wire bitwise too —
/// the worker mirrors the coordinator's exact session shape.
#[test]
fn pipelined_pjrt_process_shards_stay_bitwise_equal() {
    let (batch, keys) = five_head_batch(0x9A7C, 96, 8);
    let m = method_for(1);
    let mut threads = m
        .sharded_session(2)
        .keys(keys.clone())
        .executor(ExecutorKind::Pjrt)
        .pipelined(true)
        .build()
        .unwrap();
    let mut procs = m
        .sharded_session(2)
        .keys(keys)
        .executor(ExecutorKind::Pjrt)
        .pipelined(true)
        .remote(RemoteSpec::Spawn { program: Some(PathBuf::from(BIN)) })
        .wire_timeouts(quick_timeouts())
        .build()
        .unwrap();
    let a = threads.run_batch(&batch).unwrap();
    let b = procs.run_batch(&batch).unwrap();
    assert_outputs_bitwise("anchor pjrt pipelined over processes", &a, &b);
}

/// A worker killed between dispatches fails the batch with an `Err`
/// naming the shard; once a fresh worker listens on the same endpoint,
/// the session reconnects (with backoff) and the next batch is bitwise
/// clean again.
#[test]
fn killed_worker_names_the_shard_and_recovers_after_restart() {
    let (batch, keys) = five_head_batch(0xDEAD, 64, 8);
    let m = method_for(1);
    let p0 = sock_path("kill-0");
    let p1 = sock_path("kill-1");
    let _w0 = WorkerGuard::spawn_uds(&p0);
    let mut w1 = WorkerGuard::spawn_uds(&p1);

    let mut threads = thread_session(&m, 2, &keys, ExecutorKind::Cpu);
    let mut procs = m
        .sharded_session(2)
        .keys(keys)
        .executor(ExecutorKind::Cpu)
        .remote(RemoteSpec::Endpoints(vec![
            ShardEndpoint::Uds(p0.clone()),
            ShardEndpoint::Uds(p1.clone()),
        ]))
        .wire_timeouts(quick_timeouts())
        .build()
        .unwrap();

    let a = threads.run_batch(&batch).unwrap();
    let b = procs.run_batch(&batch).unwrap();
    assert_outputs_bitwise("pre-kill", &a, &b);

    w1.kill();
    let err = procs.run_batch(&batch).unwrap_err().to_string();
    assert!(err.contains("shard 1"), "must name the dead shard: {err}");

    // A fresh worker on the same socket: the next batch reconnects and
    // replays the Configure handshake without any caller intervention.
    let _w1b = WorkerGuard::spawn_uds(&p1);
    let a2 = threads.run_batch(&batch).unwrap();
    let b2 = procs.run_batch(&batch).unwrap();
    assert_outputs_bitwise("post-restart", &a2, &b2);
}

/// An endpoint nobody listens on exhausts its connect deadline and names
/// the shard; the surviving worker keeps serving (a fresh single-shard
/// session over it stays bitwise-equal to threads).
#[test]
fn unreachable_endpoint_names_the_shard_and_survivor_keeps_serving() {
    let (batch, keys) = five_head_batch(0x0FF, 64, 8);
    let m = method_for(5);
    let good = sock_path("surv-good");
    let absent = sock_path("surv-absent"); // never bound
    let _w = WorkerGuard::spawn_uds(&good);

    let short = WireTimeouts {
        connect: Duration::from_millis(200),
        read: Duration::from_secs(10),
        retries: 0,
        backoff: Duration::from_millis(10),
    };
    let mut split = m
        .sharded_session(2)
        .keys(keys.clone())
        .executor(ExecutorKind::Cpu)
        .remote(RemoteSpec::Endpoints(vec![
            ShardEndpoint::Uds(good.clone()),
            ShardEndpoint::Uds(absent),
        ]))
        .wire_timeouts(short)
        .build()
        .unwrap();
    let err = split.run_batch(&batch).unwrap_err().to_string();
    assert!(err.contains("shard 1"), "must name the unreachable shard: {err}");

    let mut threads = thread_session(&m, 1, &keys, ExecutorKind::Cpu);
    let mut survivor = m
        .sharded_session(1)
        .keys(keys)
        .executor(ExecutorKind::Cpu)
        .remote(RemoteSpec::Endpoints(vec![ShardEndpoint::Uds(good)]))
        .wire_timeouts(quick_timeouts())
        .build()
        .unwrap();
    let a = threads.run_batch(&batch).unwrap();
    let b = survivor.run_batch(&batch).unwrap();
    assert_outputs_bitwise("survivor after neighbor loss", &a, &b);
}

/// A worker that accepts the connection but never answers trips the read
/// deadline instead of hanging the coordinator, and the error names the
/// shard.
#[test]
fn mute_worker_hits_the_read_deadline() {
    let (batch, keys) = five_head_batch(0x51E7, 64, 8);
    let m = method_for(0);
    let path = sock_path("mute");
    let listener = std::os::unix::net::UnixListener::bind(&path).unwrap();
    let mute = std::thread::spawn(move || {
        // Accept, swallow every byte, answer nothing.
        if let Ok((mut s, _)) = listener.accept() {
            let mut sink = [0u8; 4096];
            while let Ok(n) = std::io::Read::read(&mut s, &mut sink) {
                if n == 0 {
                    break;
                }
            }
        }
    });

    let short = WireTimeouts {
        connect: Duration::from_secs(2),
        read: Duration::from_millis(200),
        retries: 0,
        backoff: Duration::from_millis(10),
    };
    let mut session = m
        .sharded_session(1)
        .keys(keys)
        .executor(ExecutorKind::Cpu)
        .remote(RemoteSpec::Endpoints(vec![ShardEndpoint::Uds(path.clone())]))
        .wire_timeouts(short)
        .build()
        .unwrap();
    let err = session.run_batch(&batch).unwrap_err().to_string();
    assert!(err.contains("shard 0"), "must name the deadline-missing shard: {err}");

    drop(session); // closes the coordinator side; the mute thread sees EOF
    mute.join().unwrap();
    let _ = std::fs::remove_file(&path);
}

/// TCP endpoints work end-to-end: spawn a worker on an ephemeral port,
/// parse the bound address from its stdout, and gate bitwise parity
/// through it.
#[test]
fn tcp_worker_round_trips_bitwise() {
    let (batch, keys) = five_head_batch(0x7C9, 64, 8);
    let m = method_for(1);
    let mut child = Command::new(BIN)
        .args(["worker", "--tcp", "127.0.0.1:0"])
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn tcp worker");
    let stdout = child.stdout.take().expect("worker stdout");
    let mut line = String::new();
    std::io::BufReader::new(stdout).read_line(&mut line).expect("read bound address");
    let addr = line
        .trim()
        .rsplit(' ')
        .next()
        .expect("address token in worker banner")
        .to_string();
    let mut guard = WorkerGuard(child);

    let mut threads = thread_session(&m, 1, &keys, ExecutorKind::Cpu);
    let mut procs = m
        .sharded_session(1)
        .keys(keys)
        .executor(ExecutorKind::Cpu)
        .remote(RemoteSpec::Endpoints(vec![ShardEndpoint::Tcp(addr)]))
        .wire_timeouts(quick_timeouts())
        .build()
        .unwrap();
    let a = threads.run_batch(&batch).unwrap();
    let b = procs.run_batch(&batch).unwrap();
    assert_outputs_bitwise("tcp transport", &a, &b);
    drop(procs); // send Shutdown before reaping the child
    guard.kill();
}

//! Minimal, offline-buildable stand-in for the `anyhow` crate.
//!
//! The hermetic build sandbox has no crates.io access, so the subset of the
//! anyhow API this workspace actually uses is re-implemented here: a
//! string-backed dynamic [`Error`], the [`Result`] alias, the `anyhow!` /
//! `bail!` / `ensure!` macros, and the [`Context`] extension trait for both
//! `Result` and `Option`. Semantics match anyhow where it matters:
//! `Error` intentionally does **not** implement `std::error::Error`, so the
//! blanket `From<E: std::error::Error>` conversion (what makes `?` work on
//! io/parse/backend errors inside `anyhow::Result` functions) does not
//! conflict with the reflexive `From<Error>`.

use std::fmt;

/// Dynamic error: a message plus the chain of contexts wrapped around it.
pub struct Error {
    msg: String,
    /// Outermost-first context chain, rendered like anyhow's `{:#}` would.
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string(), chain: Vec::new() }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The root cause message (innermost).
    pub fn root_cause(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.first() {
            Some(outer) => write!(f, "{outer}"),
            None => write!(f, "{}", self.msg),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in &self.chain {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($msg:expr $(,)?) => { $crate::Error::msg($msg) };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Early-return with an [`Error`] when the condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e)?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(err.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_render_outermost_first() {
        let err: Error = Error::msg("root").context("outer");
        assert_eq!(format!("{err}"), "outer");
        assert_eq!(format!("{err:?}"), "outer: root");
        assert_eq!(err.root_cause(), "root");
    }

    #[test]
    fn macros_compile_and_fire() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert!(f(3).is_ok());
        assert_eq!(f(0).unwrap_err().to_string(), "zero");
        assert!(f(-1).unwrap_err().to_string().contains("negative"));
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let err = x.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
    }
}

//! Offline stub of the `xla` (PJRT) crate surface used by this workspace.
//!
//! The hermetic sandbox cannot build the real PJRT bindings, so this crate
//! keeps the *types* compiling and the host-side [`Literal`] container fully
//! functional (construction, reshape, readback), while every operation that
//! would need a real PJRT backend ([`PjRtClient::cpu`], compilation,
//! execution) returns a clear "backend unavailable" error at runtime. The
//! serving stack degrades gracefully: artifact-dependent tests skip, the
//! `MockEngine` control-plane path is unaffected, and swapping in a real
//! `xla` checkout at `rust/vendor/xla` (or a registry dependency) restores
//! the PJRT path without touching any call site. See DESIGN.md §8.

use std::fmt;

/// Backend error; implements `std::error::Error` so it converts into
/// `anyhow::Error` through `?`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} unavailable: built against the offline xla stub \
         (vendor/xla); install the real PJRT-backed xla crate to enable it"
    ))
}

/// Element types a [`Literal`] can hold (the subset this workspace uses).
#[derive(Clone, Debug, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Conversion between native element types and [`Data`] storage.
pub trait NativeType: Copy + Sized {
    fn into_data(v: Vec<Self>) -> Data;
    fn from_data(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn into_data(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn into_data(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn from_data(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host-side tensor literal: dims plus typed storage. Fully functional —
/// only device transfer/execution requires the real backend.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::into_data(v.to_vec()) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: vec![], data: T::into_data(vec![v]) }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count < 0 || count as usize != self.data.len() {
            return Err(Error(format!(
                "reshape to {:?} needs {count} elements, literal has {}",
                dims,
                self.data.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Read the elements back out (type must match storage).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_data(&self.data).ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Decompose a tuple literal. The stub never produces tuples (that
    /// requires execution), so a stub literal decomposes to itself.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Ok(vec![self])
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }
}

/// Array shape wrapper.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module proto (stubbed).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HLO text parsing"))
    }
}

/// XLA computation handle (stubbed).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client (stubbed): construction reports unavailability so callers
/// fail fast with a actionable message instead of at first execution.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PJRT compilation"))
    }
}

/// Device buffer handle (stubbed).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

/// Loaded executable (stubbed).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execution"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_type_mismatch_errors() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<f32>().is_err());
        assert!(l.to_vec::<i32>().is_ok());
    }

    #[test]
    fn reshape_count_checked() {
        let l = Literal::vec1(&[1.0f32; 6]);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn scalar_rank0() {
        let l = Literal::scalar(7i32);
        assert!(l.array_shape().unwrap().dims().is_empty());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }
}
